//! Bench E5: fault tolerance — "update the code and rerun", where only the
//! failed fraction re-executes.
//!
//! Injects failures into f ∈ {10%, 30%, 50%} of a 40-task grid, then
//! measures the rerun (against the warm cache) vs the original full run.
//! Expected shape: rerun time ≈ f × full time + orchestration overhead.

use memento::bench::Suite;
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const N: usize = 40;
const TASK_MS: u64 = 10;

fn matrix() -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..N as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn main() {
    let mut suite = Suite::new("E5 — failure injection & selective rerun");
    let td = TempDir::new("bench-fault").unwrap();
    let m = matrix();

    for fail_pct in [10usize, 30, 50] {
        let cache = Arc::new(ResultCache::open(td.join(&format!("c{fail_pct}"))).unwrap());
        let fail_below = N * fail_pct / 100;

        // Full (buggy) run: tasks with i < fail_below fail.
        let full = suite
            .bench_with_setup(
                format!("full run, {fail_pct}% failing"),
                0,
                5,
                || cache.clear().unwrap(),
                |_| {
                    let c = Arc::clone(&cache);
                    let r = Memento::new(move |ctx| {
                        std::thread::sleep(Duration::from_millis(TASK_MS));
                        let i = ctx.param_i64("i")? as usize;
                        if i < fail_below {
                            Err(memento::coordinator::error::MementoError::experiment(
                                "injected",
                            ))
                        } else {
                            Ok(Json::int(i as i64))
                        }
                    })
                    .workers(4)
                    .with_cache(Arc::clone(&c))
                    .run(&m)
                    .unwrap();
                    assert_eq!(r.n_failed(), fail_below);
                },
            )
            .clone();

        // Fixed rerun: cache restores the successes, only failures execute.
        // Setup re-invalidates the failed tasks' cache entries each
        // iteration (the rerun itself writes them, so they must be evicted
        // to measure the same rerun repeatedly).
        let failed_ids: Vec<_> = memento::coordinator::expand::expand(&m)
            .into_iter()
            .filter(|s| (s.get("i").and_then(|v| v.as_i64()).unwrap() as usize) < fail_below)
            .map(|s| s.id("v1"))
            .collect();
        let executed = Arc::new(AtomicUsize::new(0));
        let rerun = suite
            .bench_with_setup(
                format!("rerun after fix, {fail_pct}% failed"),
                1,
                5,
                || {
                    for id in &failed_ids {
                        cache.invalidate(id);
                    }
                    executed.store(0, Ordering::SeqCst);
                },
                |_| {
                    let c = Arc::clone(&cache);
                    let e = Arc::clone(&executed);
                    let r = Memento::new(move |ctx| {
                        e.fetch_add(1, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_millis(TASK_MS));
                        Ok(Json::int(ctx.param_i64("i")?))
                    })
                    .workers(4)
                    .with_cache(Arc::clone(&c))
                    .run(&m)
                    .unwrap();
                    assert_eq!(r.n_failed(), 0);
                    assert_eq!(
                        executed.load(Ordering::SeqCst),
                        fail_below,
                        "only failures may re-execute"
                    );
                },
            )
            .clone();

        suite.note(format!(
            "rerun/full = {:.2} (work fraction {:.2})",
            rerun.p50 / full.p50,
            fail_pct as f64 / 100.0
        ));
    }

    suite.finish();
    println!("E5 shape check: rerun/full should track the failed fraction.");
}
