//! Bench E6: scheduling overhead.
//!
//! The paper's value proposition assumes the orchestrator itself is free:
//! per-task overhead (expansion + hashing + dispatch + collection) must be
//! orders of magnitude below any real experiment. Measures end-to-end runs
//! of no-op experiment functions at 10²–10⁴ tasks across worker counts.

use memento::bench::Suite;
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::memento::Memento;
use memento::util::json::Json;

fn flat_matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn main() {
    let mut suite = Suite::new("E6 — scheduler overhead (no-op tasks)");

    for &n in &[100usize, 1_000, 10_000] {
        let matrix = flat_matrix(n);
        for &workers in &[1usize, 4, 8] {
            let stats = suite
                .bench_with_setup(
                    format!("{n} no-op tasks, {workers} workers"),
                    1,
                    if n >= 10_000 { 5 } else { 10 },
                    || (),
                    |_| {
                        let m = Memento::new(|_| Ok(Json::Null)).workers(workers);
                        let r = m.run(&matrix).unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .clone();
            suite.note(format!(
                "{:.1}µs/task",
                stats.mean / n as f64 * 1e6
            ));
        }
    }

    // Overhead with the full reliability pipeline on (cache + checkpoint).
    let td = memento::util::fs::TempDir::new("bench-sched").unwrap();
    let matrix = flat_matrix(1_000);
    let stats = suite
        .bench_with_setup(
            "1000 no-op tasks + cache + checkpoint",
            0,
            5,
            || {
                let dir = td.join(&format!("run-{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                dir
            },
            |dir| {
                let m = Memento::new(|_| Ok(Json::Null))
                    .workers(4)
                    .with_cache_dir(dir.join("cache"))
                    .with_checkpoint_dir(dir.join("run"))
                    .checkpoint_flush_every(100);
                let r = m.run(&matrix).unwrap();
                assert_eq!(r.len(), 1000);
            },
        )
        .clone();
    suite.note(format!("{:.1}µs/task incl. persistence", stats.mean / 1e3 * 1e6));

    suite.finish();
}
