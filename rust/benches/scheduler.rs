//! Bench E6: scheduling overhead — before/after the work-stealing rewrite.
//!
//! The paper's value proposition assumes the orchestrator itself is free:
//! per-task overhead (expansion + hashing + dispatch + collection) must be
//! orders of magnitude below any real experiment.
//!
//! Two layers of measurement:
//!
//! 1. **Scheduler-level A/B** — the retained `run_all_unbatched` reference
//!    (one boxed closure + Arc clones + channel send per task) vs the
//!    chunked `run_all`, on identical no-op specs. Both run on the current
//!    work-stealing pool, so the delta isolates per-task dispatch overhead
//!    (boxing/channel vs chunking); the old single-mutex queue's
//!    contention was removed for both paths and is *not* part of this A/B
//!    — recorded speedups are a lower bound on the improvement over the
//!    seed design. The per-task delta is the headline number recorded in
//!    `BENCH_sched_cache.json`.
//! 2. **End-to-end** — full `Memento::run` of no-op experiment functions at
//!    10²–10⁴ tasks across worker counts (hashing, context, metrics all
//!    included), plus a run with the persistence pipeline on.

use memento::bench::{sched_cache_trajectory_path, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::memento::Memento;
use memento::coordinator::results::{TaskOutcome, TaskStatus};
use memento::coordinator::scheduler::{
    run_all, run_all_unbatched, SchedulerOptions,
};
use memento::coordinator::task::TaskSpec;
use memento::util::json::Json;
use std::sync::Arc;

fn flat_matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn noop_specs(n: usize) -> Vec<TaskSpec> {
    (0..n)
        .map(|i| TaskSpec {
            params: vec![("i".to_string(), pv_int(i as i64))],
            index: i,
            exp: None,
        })
        .collect()
}

fn noop_job() -> Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync> {
    Arc::new(|spec: &TaskSpec| TaskOutcome {
        spec: spec.clone(),
        id: memento::coordinator::task::TaskId(String::new()),
        status: TaskStatus::Success,
        value: None,
        failure: None,
        duration_secs: 0.0,
        from_cache: false,
        attempts: 1,
    })
}

fn main() {
    let mut suite = Suite::new("E6 — scheduler overhead (no-op tasks)");
    let mut extras: Vec<(String, Json)> = Vec::new();

    // --- scheduler-level A/B: per-task dispatch cost ----------------------
    let ab_n = 10_000usize;
    for &workers in &[1usize, 4, 8] {
        let job = noop_job();
        let opts = SchedulerOptions { workers, fail_fast: false };

        let job2 = Arc::clone(&job);
        let before = suite
            .bench_with_setup(
                format!("dispatch {ab_n} per-task-boxed, {workers}w"),
                1,
                5,
                || noop_specs(ab_n),
                |specs| {
                    let r = run_all_unbatched(specs, &opts, Arc::clone(&job2), None, None);
                    assert_eq!(r.outcomes.len(), ab_n);
                },
            )
            .clone();
        suite.note(format!("{:.2}µs/task", before.mean / ab_n as f64 * 1e6));

        let job3 = Arc::clone(&job);
        let after = suite
            .bench_with_setup(
                format!("dispatch {ab_n} chunked-stealing, {workers}w"),
                1,
                5,
                || noop_specs(ab_n),
                |specs| {
                    let r = run_all(specs, &opts, Arc::clone(&job3), None);
                    assert_eq!(r.outcomes.len(), ab_n);
                },
            )
            .clone();
        let speedup = before.mean / after.mean;
        suite.note(format!(
            "{:.2}µs/task, {speedup:.1}x vs per-task",
            after.mean / ab_n as f64 * 1e6
        ));
        extras.push((
            format!("dispatch_{workers}w_{ab_n}tasks"),
            Json::obj(vec![
                ("per_task_boxed_us", Json::Num(before.mean / ab_n as f64 * 1e6)),
                ("chunked_us", Json::Num(after.mean / ab_n as f64 * 1e6)),
                ("speedup", Json::Num(speedup)),
            ]),
        ));
        println!(
            "E6 headline ({workers}w): per-task dispatch {:.2}µs → {:.2}µs ({speedup:.1}x)",
            before.mean / ab_n as f64 * 1e6,
            after.mean / ab_n as f64 * 1e6,
        );
    }

    // --- end-to-end: full Memento pipeline --------------------------------
    for &n in &[100usize, 1_000, 10_000] {
        let matrix = flat_matrix(n);
        for &workers in &[1usize, 4, 8] {
            let stats = suite
                .bench_with_setup(
                    format!("{n} no-op tasks, {workers} workers"),
                    1,
                    if n >= 10_000 { 5 } else { 10 },
                    || (),
                    |_| {
                        let m = Memento::new(|_| Ok(Json::Null)).workers(workers);
                        let r = m.run(&matrix).unwrap();
                        assert_eq!(r.len(), n);
                    },
                )
                .clone();
            suite.note(format!(
                "{:.1}µs/task",
                stats.mean / n as f64 * 1e6
            ));
        }
    }

    // Overhead with the full reliability pipeline on (cache + checkpoint).
    let td = memento::util::fs::TempDir::new("bench-sched").unwrap();
    let matrix = flat_matrix(1_000);
    let stats = suite
        .bench_with_setup(
            "1000 no-op tasks + cache + checkpoint",
            0,
            5,
            || {
                let dir = td.join(&format!("run-{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                dir
            },
            |dir| {
                let m = Memento::new(|_| Ok(Json::Null))
                    .workers(4)
                    .with_cache_dir(dir.join("cache"))
                    .with_checkpoint_dir(dir.join("run"))
                    .checkpoint_flush_every(100);
                let r = m.run(&matrix).unwrap();
                assert_eq!(r.len(), 1000);
            },
        )
        .clone();
    suite.note(format!("{:.1}µs/task incl. persistence", stats.mean / 1e3 * 1e6));

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
