//! Bench E8: span-tracing overhead — the price of `--trace-dir`.
//!
//! Tracing records five span events per executed attempt into striped
//! lock-free-ish buffers drained by one sink thread; the scheduler hot
//! path only stamps a monotonic timestamp and pushes into a striped
//! `Vec`. This bench runs the same no-op matrix (the worst case: real
//! experiment functions bury the stamps under seconds of compute) with
//! tracing off and on, and appends `trace_overhead_off_8w_<n>tasks` /
//! `trace_overhead_on_8w_<n>tasks` rows to `BENCH_sched_cache.json`.
//!
//! Row schema (per run, under `extras`):
//!   - `trace_overhead_off_8w_<n>tasks`: `{ us_per_task }`
//!   - `trace_overhead_on_8w_<n>tasks`:  `{ us_per_task, overhead_us_per_task,
//!      on_over_off, spans_written }`
//!
//! Run on a toolchain host from `rust/`:
//! `cargo bench --bench trace` (the tier-1 container has no cargo).

use memento::bench::{sched_cache_trajectory_path, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::memento::Memento;
use memento::obs::trace::{read_trace, TRACE_FILE};
use memento::prelude::{MementoError, TaskContext};
use memento::util::fs::TempDir;
use memento::util::json::Json;

fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    Ok(Json::int(ctx.param_i64("i")?))
}

fn flat_matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn main() {
    let mut suite = Suite::new("E8 — span-tracing overhead");
    let mut extras: Vec<(String, Json)> = Vec::new();

    let workers = 8usize;
    let n = 400usize;
    let matrix = flat_matrix(n);

    let off = suite
        .bench_with_setup(
            format!("{n} no-op tasks, {workers} threads, trace off"),
            1,
            5,
            || (),
            |_| {
                let r = Memento::new(exp).workers(workers).run(&matrix).unwrap();
                assert_eq!(r.len(), n);
            },
        )
        .clone();
    suite.note(format!("{:.1}µs/task baseline", off.mean / n as f64 * 1e6));
    extras.push((
        format!("trace_overhead_off_{workers}w_{n}tasks"),
        Json::obj(vec![("us_per_task", Json::Num(off.mean / n as f64 * 1e6))]),
    ));

    // Each iteration traces into a fresh dir so the sink always starts
    // from an empty file; the TempDir drop cleans up after the timing.
    let mut spans_written = 0u64;
    let on = suite
        .bench_with_setup(
            format!("{n} no-op tasks, {workers} threads, trace on"),
            1,
            5,
            || TempDir::new("bench-trace").unwrap(),
            |td| {
                let r = Memento::new(exp)
                    .workers(workers)
                    .trace_to(td.path())
                    .run(&matrix)
                    .unwrap();
                assert_eq!(r.len(), n);
                let trace = read_trace(&td.path().join(TRACE_FILE)).unwrap();
                assert_eq!(trace.dropped, Some(0), "bench run must not drop spans");
                spans_written = trace.spans.len() as u64;
            },
        )
        .clone();
    let overhead_us = (on.mean - off.mean) / n as f64 * 1e6;
    suite.note(format!(
        "{:.1}µs/task, +{overhead_us:.1}µs/task over baseline ({} spans)",
        on.mean / n as f64 * 1e6,
        spans_written
    ));
    extras.push((
        format!("trace_overhead_on_{workers}w_{n}tasks"),
        Json::obj(vec![
            ("us_per_task", Json::Num(on.mean / n as f64 * 1e6)),
            ("overhead_us_per_task", Json::Num(overhead_us)),
            ("on_over_off", Json::Num(on.mean / off.mean)),
            ("spans_written", Json::int(spans_written as i64)),
        ]),
    ));
    println!(
        "E8 headline: tracing costs {overhead_us:.1}µs/task on no-op tasks ({:.2}x baseline)",
        on.mean / off.mean
    );

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
