//! Bench E7: the end-to-end §3 grid (one timed pass + per-stage breakdown).
//!
//! Runs the exact 45-task paper grid once (cold) and once warm, prints the
//! accuracy pivot, per-model mean task cost, and — when artifacts exist —
//! the extended 60-task grid including the AOT MLP.

use memento::bench::Suite;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::experiments::grid;
use memento::runtime::artifact::shared_store;
use memento::util::fs::TempDir;
use std::sync::Arc;

fn main() {
    let mut suite = Suite::new("E7 — end-to-end §3 grid");
    let td = TempDir::new("bench-e2e").unwrap();
    let workers = memento::util::pool::num_cpus().max(4);

    // --- the paper's exact 45-task grid -----------------------------------
    let matrix = grid::paper_matrix();
    let cache = Arc::new(ResultCache::open(td.join("cache")).unwrap());

    let cold = suite
        .bench_with_setup(
            "paper grid cold (45 tasks, 5-fold)",
            0,
            2,
            || cache.clear().unwrap(),
            |_| {
                let r = Memento::new(grid::grid_exp_fn(None))
                    .workers(workers)
                    .with_cache(Arc::clone(&cache))
                    .run(&matrix)
                    .unwrap();
                assert_eq!(r.len(), 45);
                assert_eq!(r.n_failed(), 0);
            },
        )
        .clone();
    suite.note(format!("{:.1} tasks/s", 45.0 / cold.mean));

    let warm = suite
        .bench("paper grid warm (cache hits)", 1, 5, |_| {
            let r = Memento::new(grid::grid_exp_fn(None))
                .workers(workers)
                .with_cache(Arc::clone(&cache))
                .run(&matrix)
                .unwrap();
            assert_eq!(r.n_cached(), 45);
        })
        .clone();
    suite.note(format!("cold/warm {:.0}x", cold.mean / warm.mean));

    // Per-model cost breakdown + pivot from a fresh run.
    cache.clear().unwrap();
    let r = Memento::new(grid::grid_exp_fn(None))
        .workers(workers)
        .run(&matrix)
        .unwrap();
    println!("\naccuracy pivot (45-task paper grid):");
    println!("{}", r.pivot("dataset", "model", "accuracy").render());
    println!("mean task duration by model:");
    for (model, mean, n) in r.mean_by("model", "accuracy") {
        let durs: Vec<f64> = r
            .filter(&[("model", model.clone())])
            .iter()
            .map(|o| o.duration_secs)
            .collect();
        let mean_dur = durs.iter().sum::<f64>() / durs.len() as f64;
        println!("  {model:<14} {n:>2} tasks  mean {mean_dur:>8.3}s  acc {mean:.4}");
    }

    // --- extended grid with the AOT MLP ------------------------------------
    match shared_store() {
        Ok(store) => {
            let ext = grid::extended_matrix();
            let stats = suite
                .bench("extended grid incl. MLP (60 tasks)", 0, 2, |_| {
                    let r = Memento::new(grid::grid_exp_fn(Some(Arc::clone(&store))))
                        .workers(workers)
                        .run(&ext)
                        .unwrap();
                    assert_eq!(r.len(), 60);
                    assert_eq!(r.n_failed(), 0);
                })
                .clone();
            suite.note(format!("{:.1} tasks/s incl. PJRT", 60.0 / stats.mean));
        }
        Err(e) => println!("extended grid skipped (no artifacts): {e}"),
    }

    suite.finish();
}
