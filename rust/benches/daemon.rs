//! Bench E12: daemon submission overhead and multi-tenant throughput.
//!
//! The daemon puts a framed handshake, an admission queue, and an event
//! tee between the client and the coordinator. `daemon_submit_latency`
//! prices the full round trip for the smallest possible run (one no-op
//! task): connect → `Submit` → admission → scheduler launch → lease →
//! execute → `Event` stream → `run_complete`. `daemon_2tenant_throughput`
//! drives two tenants' disjoint grids through one daemon concurrently and
//! reports aggregate tasks/sec through the shared pool. Both rows append
//! to `BENCH_sched_cache.json` next to the scheduler/cache trajectory.
//!
//! Run on a toolchain host from `rust/`:
//! `cargo bench --bench daemon` (the tier-1 container has no cargo).

#![cfg_attr(not(unix), allow(dead_code, unused_imports))]

use memento::bench::{sched_cache_trajectory_path, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::prelude::{MementoError, Registry, TaskContext};
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const TOKEN: &str = "bench-daemon-token";

fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    Ok(Json::int(ctx.param_i64("i")?))
}

#[cfg(not(unix))]
fn main() {
    eprintln!("the daemon bench needs the unix-gated daemon module; skipping on this platform");
}

#[cfg(unix)]
fn main() {
    use memento::daemon::{Daemon, DaemonClient, DaemonOptions, SubmitOptions};
    use memento::ipc::transport::Transport;
    use memento::ipc::worker::{serve_remote, RemoteWorkerOptions};

    let mut suite = Suite::new("E12 — daemon submission service");
    let mut extras: Vec<(String, Json)> = Vec::new();

    let td = TempDir::new("bench-daemon").expect("bench tempdir");
    let mut options = DaemonOptions::new(td.join("root"));
    options.token = Some(TOKEN.to_string());
    options.max_in_flight = 2;
    options.workers_per_run = 2;
    let daemon = Daemon::start(
        Registry::solo(Arc::new(exp)),
        options,
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
        &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
    )
    .expect("start bench daemon");
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let endpoint = daemon.worker_endpoint();
            std::thread::spawn(move || {
                let _ = serve_remote(
                    Arc::new(Registry::solo(Arc::new(exp))),
                    &endpoint,
                    RemoteWorkerOptions {
                        token: Some(TOKEN.to_string()),
                        give_up_after: Some(std::time::Duration::from_secs(2)),
                        quiet: true,
                        ..RemoteWorkerOptions::default()
                    },
                );
            })
        })
        .collect();

    // A fresh stamp per submission keeps every run's cells distinct (and
    // its label unique), so each iteration measures real execution, never
    // a cache restore of the previous iteration.
    let stamp = AtomicU64::new(0);
    let client = DaemonClient::new(daemon.endpoint().clone(), Some(TOKEN.to_string()));

    let lat = suite
        .bench("daemon_submit_latency", 2, 20, |_| {
            let s = stamp.fetch_add(1, Ordering::SeqCst) as i64;
            let matrix = ConfigMatrix::builder()
                .param("i", vec![pv_int(s)])
                .build()
                .unwrap();
            let opts = SubmitOptions {
                tenant: "bench".to_string(),
                label: Some(format!("lat-{s}")),
                ..SubmitOptions::default()
            };
            let mut handle = client.submit(&matrix, &opts).expect("submit");
            while handle.next_event().expect("event stream").is_some() {}
        })
        .clone();
    suite.note(format!(
        "{:.2}ms submit→run_complete for a 1-task grid (handshake + admission + lease + event tee)",
        lat.mean * 1e3
    ));

    let n = 50i64;
    let thr = suite
        .bench("daemon_2tenant_throughput", 1, 5, |_| {
            let s = stamp.fetch_add(1, Ordering::SeqCst) as i64;
            let handles: Vec<_> = [("alice", 0i64), ("bob", n)]
                .map(|(tenant, offset)| {
                    let endpoint = daemon.endpoint().clone();
                    std::thread::spawn(move || {
                        let c = DaemonClient::new(endpoint, Some(TOKEN.to_string()));
                        let matrix = ConfigMatrix::builder()
                            .param("i", (offset..offset + n).map(pv_int).collect())
                            .param("stamp", vec![pv_int(s)])
                            .build()
                            .unwrap();
                        let opts = SubmitOptions {
                            tenant: tenant.to_string(),
                            label: Some(format!("thr-{tenant}-{s}")),
                            ..SubmitOptions::default()
                        };
                        let mut h = c.submit(&matrix, &opts).expect("submit");
                        while h.next_event().expect("event stream").is_some() {}
                    })
                })
                .into_iter()
                .collect();
            for h in handles {
                h.join().expect("tenant client thread");
            }
        })
        .clone();
    let tasks_per_sec = 2.0 * n as f64 / thr.mean;
    suite.note(format!(
        "{tasks_per_sec:.0} no-op tasks/sec across 2 concurrent tenants ({n} cells each, shared 2-worker pool)"
    ));
    extras.push((
        "daemon_service".to_string(),
        Json::obj(vec![
            ("submit_latency_ms", Json::Num(lat.mean * 1e3)),
            ("two_tenant_tasks_per_sec", Json::Num(tasks_per_sec)),
        ]),
    ));
    println!(
        "E12 headline: {:.2}ms 1-task submit round trip, {tasks_per_sec:.0} tasks/sec for 2 tenants",
        lat.mean * 1e3
    );

    daemon.shutdown();
    daemon.wait();
    for w in workers {
        let _ = w.join();
    }

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
