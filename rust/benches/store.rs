//! Bench: the cross-run segment-log result store.
//!
//! Micro-costs for the four store operations on the hot path — append a
//! result (`store_put_<wire>`), cold-read one back (`store_get_cold_<wire>`),
//! answer a parameter predicate over a 10k-record store
//! (`store_query_10k_<wire>`), and fold sealed segments
//! (`compact_fold`) — recorded into `BENCH_sched_cache.json` alongside
//! the scheduler/cache rows. The query rows are the evidence for the
//! lazy-scan claim: matching never materializes non-matching records.

use memento::bench::{black_box, sched_cache_trajectory_path, Suite};
use memento::store::query::{parse_predicates, QueryOptions};
use memento::store::ResultStore;
use memento::util::codec::WireFormat;
use memento::util::fs::TempDir;
use memento::util::json::Json;

const MODELS: [&str; 4] = ["svc", "tree", "forest", "mlp"];

fn params_for(i: usize) -> Json {
    Json::obj(vec![
        ("model", Json::str(MODELS[i % MODELS.len()])),
        ("lr", Json::Num((i % 100) as f64 / 100.0)),
        ("fold", Json::int((i % 5) as i64)),
    ])
}

fn value_for(i: usize) -> Json {
    Json::obj(vec![
        ("accuracy", Json::Num(0.5 + (i % 50) as f64 / 100.0)),
        ("folds", Json::Arr(vec![Json::Num(0.9); 5])),
    ])
}

fn main() {
    let mut suite = Suite::new("store — cross-run segment log");
    let td = TempDir::new("bench-store").unwrap();
    let mut extras: Vec<(String, Json)> = Vec::new();

    for wire in [WireFormat::Binary, WireFormat::Json] {
        let tag = match wire {
            WireFormat::Binary => "binary",
            WireFormat::Json => "json",
        };

        // --- put ------------------------------------------------------------
        let store = ResultStore::open(td.join(format!("put-{tag}"))).unwrap();
        store.set_wire(wire);
        store.begin_run("bench").unwrap();
        let mut k = 0usize;
        let put = suite
            .bench(format!("store.put ({tag}, no fsync)"), 100, 1000, |_| {
                // Fresh ids: every put appends (values repeat, so the
                // content-hash table sees dedup pressure too).
                store
                    .put_result(&format!("task-{k}"), &params_for(k), &value_for(k))
                    .unwrap();
                k += 1;
            })
            .clone();
        extras.push((format!("store_put_{tag}"), Json::Num(put.mean * 1e9)));

        // --- cold get -------------------------------------------------------
        // Reopen so the index is rebuilt from record headers and every get
        // reads its frame from disk (no warm process state).
        let dir = store.dir();
        drop(store);
        let cold = ResultStore::open(&dir).unwrap();
        let get = suite
            .bench(format!("store.get (cold, {tag})"), 100, 1000, |i| {
                black_box(cold.get_result(&format!("task-{}", i % 1000)).unwrap());
            })
            .clone();
        extras.push((format!("store_get_cold_{tag}"), Json::Num(get.mean * 1e9)));

        // --- query over 10k records ----------------------------------------
        let qstore = ResultStore::open(td.join(format!("query-{tag}"))).unwrap();
        qstore.set_wire(wire);
        qstore.set_auto_compact(false);
        qstore.begin_run("bench").unwrap();
        for i in 0..10_000 {
            qstore
                .put_result(&format!("q-{i}"), &params_for(i), &value_for(i))
                .unwrap();
        }
        let preds = parse_predicates("model=svc, lr<=0.1").unwrap();
        let n_match = qstore.query(&preds, &QueryOptions::default()).unwrap().len();
        let q = suite
            .bench(format!("store.query 10k ({tag})"), 2, 20, |_| {
                let rows = qstore.query(&preds, &QueryOptions::default()).unwrap();
                assert_eq!(rows.len(), n_match);
                black_box(rows);
            })
            .clone();
        suite.note(format!("{n_match} of 10000 records match"));
        extras.push((
            format!("store_query_10k_{tag}"),
            Json::obj(vec![
                ("query_s", Json::Num(q.mean)),
                ("matches", Json::int(n_match as i64)),
            ]),
        ));
    }

    // --- compaction ---------------------------------------------------------
    // Many small sealed segments full of superseded versions: each timed
    // pass re-seeds the store, then folds it down to one segment.
    let compact = suite
        .bench_with_setup(
            "store.compact (fold sealed segments)",
            0,
            10,
            || {},
            |i| {
                let dir = td.join(format!("compact-{i}"));
                let store = ResultStore::open(&dir).unwrap();
                store.set_auto_compact(false);
                store.set_segment_max(16 * 1024);
                store.begin_run("bench").unwrap();
                for j in 0..2000 {
                    // 4 versions per id → 75% of records are dead.
                    store
                        .put_result(&format!("c-{}", j % 500), &params_for(j), &value_for(j))
                        .unwrap();
                }
                store.seal_active().unwrap();
                let report = store.compact().unwrap();
                assert!(report.input_segments > 0, "must fold at least one segment");
                black_box(report);
            },
        )
        .clone();
    extras.push(("compact_fold".to_string(), Json::Num(compact.mean)));

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
