//! Bench E3: result caching — "avoid running duplicate experiments".
//!
//! Headline series: cold run vs warm re-run of the toy ML grid (the §2
//! claim is that the warm path costs ~nothing). Plus put/get micro-costs
//! and the two-tier split: a warm `get` served by the in-memory tier vs
//! the same entry forced back to the disk tier (`drop_memory`), which is
//! the before/after evidence for `BENCH_sched_cache.json` — the old cache
//! paid the disk-tier cost on *every* hit.

use memento::bench::{black_box, sched_cache_trajectory_path, Suite};
use memento::config::value::pv_int;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::coordinator::task::TaskSpec;
use memento::experiments::grid;
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::Arc;

fn main() {
    let mut suite = Suite::new("E3 — result cache");
    let td = TempDir::new("bench-cache").unwrap();
    let mut extras: Vec<(String, Json)> = Vec::new();

    // --- micro: put/get ----------------------------------------------------
    let cache = ResultCache::open(td.join("micro")).unwrap();
    let value = Json::obj(vec![
        ("accuracy", Json::Num(0.9321)),
        ("folds", Json::Arr(vec![Json::Num(0.9); 5])),
    ]);
    let specs: Vec<TaskSpec> = (0..1000)
        .map(|i| TaskSpec {
            params: vec![("i".into(), pv_int(i as i64))],
            index: i,
            exp: None,
        })
        .collect();
    let ids: Vec<_> = specs.iter().map(|s| s.id("v1")).collect();

    let mut k = 0usize;
    suite.bench("cache.put (default, no fsync)", 100, 1000, |i| {
        cache.put(&ids[i % 1000], &specs[i % 1000], &value).unwrap();
        k += 1;
    });
    let durable = ResultCache::open(td.join("durable")).unwrap().durable(true);
    suite.bench("cache.put (durable, fsync)", 20, 200, |i| {
        durable.put(&ids[i % 1000], &specs[i % 1000], &value).unwrap();
    });
    suite.note("§Perf-L3: fsync cost isolated");

    // Warm hit: memory tier, zero filesystem I/O (asserted via stats).
    let (mem0, disk0) = cache.stats().tier_snapshot();
    let warm_hit = suite
        .bench("cache.get (hit, memory tier)", 100, 1000, |i| {
            black_box(cache.get(&ids[i % 1000]).unwrap());
        })
        .clone();
    let (mem1, disk1) = cache.stats().tier_snapshot();
    assert_eq!(disk1, disk0, "warm hits must not read disk");
    assert_eq!(mem1 - mem0, 1100, "warmup + timed iters all memory-tier");
    suite.note(format!("{:.0}ns/get, 0 disk reads", warm_hit.mean * 1e9));

    // Disk-tier hit: demote residency before each batch of gets, so every
    // get re-reads and re-parses its on-disk entry (the pre-two-tier cost
    // of *every* hit).
    let disk_hit = suite
        .bench("cache.get (hit, disk tier)", 1, 10, |_| {
            cache.drop_memory();
            for i in 0..1000 {
                black_box(cache.get(&ids[i]).unwrap());
            }
        })
        .clone();
    let disk_per_get = disk_hit.mean / 1000.0;
    suite.note(format!("{:.0}ns/get incl. read+parse", disk_per_get * 1e9));
    let tier_ratio = disk_per_get / warm_hit.mean;
    extras.push((
        "warm_get".to_string(),
        Json::obj(vec![
            ("memory_tier_ns", Json::Num(warm_hit.mean * 1e9)),
            ("disk_tier_ns", Json::Num(disk_per_get * 1e9)),
            ("ratio", Json::Num(tier_ratio)),
        ]),
    ));
    println!(
        "E3 tier headline: warm get {:.0}ns (memory) vs {:.0}ns (disk) → {tier_ratio:.1}x",
        warm_hit.mean * 1e9,
        disk_per_get * 1e9,
    );

    // Storage-codec delta on the disk tier: the default cache above wrote
    // tagged-binary entries whose cold `get` lazily scans out just the
    // "value" field; this one forces JSON at rest. Same files, same tier
    // demotion — the difference is the per-entry decode.
    let jcache = ResultCache::open(td.join("micro-json"))
        .unwrap()
        .storage_format(memento::util::codec::WireFormat::Json);
    for i in 0..1000 {
        jcache.put(&ids[i], &specs[i], &value).unwrap();
    }
    let json_disk = suite
        .bench("cache.get (hit, disk tier, json store)", 1, 10, |_| {
            jcache.drop_memory();
            for i in 0..1000 {
                black_box(jcache.get(&ids[i]).unwrap());
            }
        })
        .clone();
    let json_per_get = json_disk.mean / 1000.0;
    suite.note(format!(
        "{:.0}ns/get json store vs {:.0}ns binary ({:.2}x)",
        json_per_get * 1e9,
        disk_per_get * 1e9,
        json_per_get / disk_per_get,
    ));
    extras.push((
        "cache_scan_bin_1000entries".to_string(),
        Json::obj(vec![
            ("binary_disk_ns", Json::Num(disk_per_get * 1e9)),
            ("json_disk_ns", Json::Num(json_per_get * 1e9)),
            ("json_over_binary", Json::Num(json_per_get / disk_per_get)),
        ]),
    ));

    let missing =
        TaskSpec { params: vec![("i".into(), pv_int(-1))], index: 0, exp: None }.id("v1");
    suite.bench("cache.get (miss)", 100, 1000, |_| {
        black_box(cache.get(&missing));
    });

    // len() is now O(1) over the index — previously a full directory scan.
    suite.bench("cache.len (indexed)", 100, 1000, |_| {
        black_box(cache.len());
    });

    // --- headline: cold vs warm grid run ------------------------------------
    let matrix = grid::toy_matrix();
    let n_tasks = memento::coordinator::expand::count_included(&matrix);

    let cache_dir = td.join("grid-cache");
    let shared = Arc::new(ResultCache::open(&cache_dir).unwrap());

    let cold = suite
        .bench_with_setup(
            format!("toy grid cold ({n_tasks} tasks)"),
            0,
            5,
            || {
                shared.clear().unwrap();
            },
            |_| {
                let m = Memento::new(grid::grid_exp_fn(None))
                    .workers(4)
                    .with_cache(Arc::clone(&shared));
                let r = m.run(&matrix).unwrap();
                assert_eq!(r.n_cached(), 0);
            },
        )
        .clone();

    // warm the cache once
    Memento::new(grid::grid_exp_fn(None))
        .with_cache(Arc::clone(&shared))
        .run(&matrix)
        .unwrap();

    let warm = suite
        .bench(format!("toy grid warm ({n_tasks} tasks)"), 2, 20, |_| {
            let m = Memento::new(grid::grid_exp_fn(None))
                .workers(4)
                .with_cache(Arc::clone(&shared));
            let r = m.run(&matrix).unwrap();
            assert_eq!(r.n_cached(), n_tasks, "all tasks must hit the cache");
        })
        .clone();

    suite.note(format!(
        "cold/warm = {:.1}x; hit-rate 100%",
        cold.mean / warm.mean
    ));
    extras.push((
        "grid_cold_vs_warm".to_string(),
        Json::obj(vec![
            ("cold_s", Json::Num(cold.mean)),
            ("warm_s", Json::Num(warm.mean)),
            ("speedup", Json::Num(cold.mean / warm.mean)),
        ]),
    ));

    println!(
        "\nE3 headline: cold {:.3}s vs warm {:.4}s → speedup {:.1}x (paper claim: warm ≈ free)",
        cold.mean,
        warm.mean,
        cold.mean / warm.mean
    );
    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
