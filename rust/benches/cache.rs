//! Bench E3: result caching — "avoid running duplicate experiments".
//!
//! Headline series: cold run vs warm re-run of the toy ML grid (the §2
//! claim is that the warm path costs ~nothing). Plus put/get micro-costs
//! and hit-rate accounting.

use memento::bench::{black_box, Suite};
use memento::config::value::pv_int;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::coordinator::task::TaskSpec;
use memento::experiments::grid;
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::Arc;

fn main() {
    let mut suite = Suite::new("E3 — result cache");
    let td = TempDir::new("bench-cache").unwrap();

    // --- micro: put/get ----------------------------------------------------
    let cache = ResultCache::open(td.join("micro")).unwrap();
    let value = Json::obj(vec![
        ("accuracy", Json::Num(0.9321)),
        ("folds", Json::Arr(vec![Json::Num(0.9); 5])),
    ]);
    let specs: Vec<TaskSpec> = (0..1000)
        .map(|i| TaskSpec {
            params: vec![("i".into(), pv_int(i as i64))],
            index: i,
        })
        .collect();
    let ids: Vec<_> = specs.iter().map(|s| s.id("v1")).collect();

    let mut k = 0usize;
    suite.bench("cache.put (default, no fsync)", 100, 1000, |i| {
        cache.put(&ids[i % 1000], &specs[i % 1000], &value).unwrap();
        k += 1;
    });
    let durable = ResultCache::open(td.join("durable")).unwrap().durable(true);
    suite.bench("cache.put (durable, fsync)", 20, 200, |i| {
        durable.put(&ids[i % 1000], &specs[i % 1000], &value).unwrap();
    });
    suite.note("§Perf-L3: fsync cost isolated");
    suite.bench("cache.get (hit)", 100, 1000, |i| {
        black_box(cache.get(&ids[i % 1000]).unwrap());
    });
    let missing = TaskSpec { params: vec![("i".into(), pv_int(-1))], index: 0 }.id("v1");
    suite.bench("cache.get (miss)", 100, 1000, |_| {
        black_box(cache.get(&missing));
    });

    // --- headline: cold vs warm grid run ------------------------------------
    let matrix = grid::toy_matrix();
    let n_tasks = memento::coordinator::expand::count_included(&matrix);

    let cache_dir = td.join("grid-cache");
    let shared = Arc::new(ResultCache::open(&cache_dir).unwrap());

    let cold = suite
        .bench_with_setup(
            format!("toy grid cold ({n_tasks} tasks)"),
            0,
            5,
            || {
                shared.clear().unwrap();
            },
            |_| {
                let m = Memento::new(grid::grid_exp_fn(None))
                    .workers(4)
                    .with_cache(Arc::clone(&shared));
                let r = m.run(&matrix).unwrap();
                assert_eq!(r.n_cached(), 0);
            },
        )
        .clone();

    // warm the cache once
    Memento::new(grid::grid_exp_fn(None))
        .with_cache(Arc::clone(&shared))
        .run(&matrix)
        .unwrap();

    let warm = suite
        .bench(format!("toy grid warm ({n_tasks} tasks)"), 2, 20, |_| {
            let m = Memento::new(grid::grid_exp_fn(None))
                .workers(4)
                .with_cache(Arc::clone(&shared));
            let r = m.run(&matrix).unwrap();
            assert_eq!(r.n_cached(), n_tasks, "all tasks must hit the cache");
        })
        .clone();

    suite.note(format!(
        "cold/warm = {:.1}x; hit-rate 100%",
        cold.mean / warm.mean
    ));

    println!(
        "\nE3 headline: cold {:.3}s vs warm {:.4}s → speedup {:.1}x (paper claim: warm ≈ free)",
        cold.mean,
        warm.mean,
        cold.mean / warm.mean
    );
    suite.finish();
}
