//! Bench E1/E6(a): configuration-matrix expansion.
//!
//! Regenerates the §3 worked example's counts (54 raw → 45 included) and
//! measures expansion + hashing throughput up to 10⁵-combination matrices —
//! the "translate the matrix to distinct experimental tasks" step must be
//! invisible next to any real experiment.

use memento::bench::{black_box, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::expand;
use memento::coordinator::memento::Memento;
use memento::coordinator::run::RunEvent;
use memento::experiments::grid;
use memento::util::json::Json;
use std::time::Instant;

fn synthetic_matrix(domains: &[usize], n_excludes: usize) -> ConfigMatrix {
    let mut b = ConfigMatrix::builder();
    for (i, &d) in domains.iter().enumerate() {
        b = b.param(format!("p{i}"), (0..d as i64).map(pv_int).collect());
    }
    for e in 0..n_excludes {
        b = b.exclude(vec![("p0", pv_int((e % domains[0]) as i64))]);
    }
    b.build().unwrap()
}

fn main() {
    let mut suite = Suite::new("E1/E6a — matrix expansion");

    // --- the paper's exact §3 example -----------------------------------
    let paper = grid::paper_matrix();
    let tasks = expand::expand(&paper);
    println!(
        "paper §3 example: raw={} excluded={} included={}",
        paper.raw_count(),
        paper.raw_count() - tasks.len(),
        tasks.len()
    );
    assert_eq!((paper.raw_count(), tasks.len()), (54, 45));

    suite.bench("expand paper grid (54 raw)", 50, 500, |_| {
        black_box(expand::expand(&paper));
    });
    suite.note("54 raw -> 45 tasks");

    suite.bench("expand+hash paper grid", 20, 200, |_| {
        for t in expand::Expansion::new(&paper) {
            black_box(t.id("v1"));
        }
    });
    suite.note("SHA-256 per task");

    // --- scaling ----------------------------------------------------------
    for (label, domains) in [
        ("1k combos (10x10x10)", vec![10, 10, 10]),
        ("10k combos (10^4)", vec![10, 10, 10, 10]),
        ("100k combos (10^5)", vec![10, 10, 10, 10, 10]),
    ] {
        let m = synthetic_matrix(&domains, 0);
        let n = m.raw_count();
        let stats = suite
            .bench(format!("expand {label}"), 3, 20, |_| {
                black_box(expand::count_included(&m));
            })
            .clone();
        suite.note(format!("{:.1}M combos/s", n as f64 / stats.mean / 1e6));
    }

    // --- exclusion cost ----------------------------------------------------
    for n_excl in [1usize, 8, 64] {
        let m = synthetic_matrix(&[10, 10, 10, 10], n_excl);
        let included = expand::count_included(&m);
        suite.bench(format!("10k combos, {n_excl} exclude rules"), 3, 20, |_| {
            black_box(expand::count_included(&m));
        });
        suite.note(format!("{included} included"));
    }

    // --- eager vs lazy throughput ------------------------------------------
    // The eager oracle materializes every TaskSpec; the lazy stream visits
    // the same combinations without allocating the product.
    let big = synthetic_matrix(&[10, 10, 10, 10, 10], 0); // 100k combos
    let eager = suite
        .bench("eager expand 100k (materialize Vec)", 2, 10, |_| {
            black_box(expand::expand(&big).len());
        })
        .clone();
    let lazy = suite
        .bench("lazy stream 100k (iterate only)", 2, 10, |_| {
            black_box(expand::Expansion::new(&big).count());
        })
        .clone();
    suite.note(format!("eager/lazy mean {:.2}x", eager.mean / lazy.mean.max(1e-12)));

    // --- first-outcome latency on a 10^12-raw matrix -----------------------
    // launch() → first TaskFinished event over a no-op experiment on a
    // matrix the eager design could never materialize (32^8 ≈ 1.1e12 raw).
    // This is the headline number for the streaming Run handle: it bounds
    // how long *any* run waits before its first result regardless of
    // matrix size.
    let mut b = ConfigMatrix::builder();
    for p in 0..8 {
        b = b.param(format!("p{p}"), (0..32).map(pv_int).collect());
    }
    let huge = b.build().unwrap();
    let mut first_event = Vec::new();
    for _ in 0..5 {
        let m = Memento::new(|_| Ok(Json::Null)).workers(2);
        let t = Instant::now();
        let run = m.launch(&huge).expect("launch");
        for ev in run.events() {
            if matches!(ev, RunEvent::TaskFinished(_)) {
                first_event.push(t.elapsed().as_secs_f64());
                break;
            }
        }
        run.cancel();
        // dropping the handle joins the (now cancelled) run thread
    }
    suite.record(
        "first-outcome latency, 10^12-raw matrix",
        first_event,
        "launch -> first TaskFinished; eager expand would OOM",
    );

    suite.finish();

    suite.write_trajectory(
        &memento::bench::sched_cache_trajectory_path(),
        vec![
            (
                "expand_eager_vs_lazy_100k".to_string(),
                Json::obj(vec![
                    ("eager_mean_s", Json::Num(eager.mean)),
                    ("lazy_mean_s", Json::Num(lazy.mean)),
                ]),
            ),
            (
                "first_outcome_latency_1e12_raw".to_string(),
                Json::obj(vec![(
                    "note",
                    Json::str("see suite row 'first-outcome latency, 10^12-raw matrix'"),
                )]),
            ),
        ],
    );
}
