//! Bench E7: IPC dispatch overhead — thread vs process vs TCP-remote.
//!
//! The process backend buys crash isolation with one socket round-trip
//! per attempt plus worker spawn amortized over the run; the remote
//! backend swaps the Unix socket for loopback TCP and a standing worker
//! pool (spawn cost paid once, before the runs). This bench quantifies
//! both prices on no-op tasks (the worst case: real experiment functions
//! bury microseconds of dispatch under seconds of compute) and records
//! `ipc_dispatch_*` (Unix-socket processes) and `ipc_dispatch_tcp_*`
//! (TCP remote) rows next to the scheduler rows in
//! `BENCH_sched_cache.json`.
//!
//! Run on a toolchain host from `rust/`:
//! `cargo bench --bench ipc` (the tier-1 container has no cargo).

#![cfg_attr(not(unix), allow(dead_code, unused_imports))]

use memento::bench::{sched_cache_trajectory_path, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::memento::Memento;
use memento::prelude::{MementoError, TaskContext};
use memento::util::codec::WireFormat;
use memento::util::json::Json;
use std::sync::Arc;

fn exp(ctx: &TaskContext) -> Result<Json, MementoError> {
    Ok(Json::int(ctx.param_i64("i")?))
}

fn flat_matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

#[cfg(not(unix))]
fn main() {
    eprintln!("the ipc bench needs unix domain sockets; skipping on this platform");
}

#[cfg(unix)]
fn main() {
    // Re-executions of this bench binary are workers: serve and exit
    // before any benching happens (and before argv parsing — the worker
    // argv is whatever cargo passed us, e.g. `--bench`).
    memento::ipc::worker::maybe_serve(Arc::new(exp));

    let mut suite = Suite::new("E7 — process-isolation dispatch overhead");
    let mut extras: Vec<(String, Json)> = Vec::new();

    let n = 200usize;
    for &workers in &[2usize, 4] {
        let matrix = flat_matrix(n);
        let thread = suite
            .bench_with_setup(
                format!("{n} no-op tasks, {workers} threads"),
                1,
                5,
                || (),
                |_| {
                    let r = Memento::new(exp).workers(workers).run(&matrix).unwrap();
                    assert_eq!(r.len(), n);
                },
            )
            .clone();
        suite.note(format!("{:.1}µs/task", thread.mean / n as f64 * 1e6));

        let process = suite
            .bench_with_setup(
                format!("{n} no-op tasks, {workers} processes"),
                1,
                3,
                || (),
                |_| {
                    let r = Memento::new(exp)
                        .isolate_processes(workers, 1)
                        .run(&matrix)
                        .unwrap();
                    assert_eq!(r.len(), n);
                },
            )
            .clone();
        let ratio = process.mean / thread.mean;
        suite.note(format!(
            "{:.1}µs/task, {ratio:.1}x thread dispatch (spawn amortized over {n})",
            process.mean / n as f64 * 1e6
        ));
        extras.push((
            format!("ipc_dispatch_{workers}w_{n}tasks"),
            Json::obj(vec![
                ("thread_us_per_task", Json::Num(thread.mean / n as f64 * 1e6)),
                ("process_us_per_task", Json::Num(process.mean / n as f64 * 1e6)),
                ("process_over_thread", Json::Num(ratio)),
            ]),
        ));
        println!(
            "E7 headline ({workers}w): dispatch {:.1}µs/task threads → {:.1}µs/task processes",
            thread.mean / n as f64 * 1e6,
            process.mean / n as f64 * 1e6,
        );

        // Wire-codec delta on the same process tier: the default run above
        // frames payloads in the tagged binary codec; this one forces the
        // JSON fallback. Same sockets, same spawns — the difference is
        // purely serialize + parse per round-trip.
        let json_wire = suite
            .bench_with_setup(
                format!("{n} no-op tasks, {workers} processes, json wire"),
                1,
                3,
                || (),
                |_| {
                    let r = Memento::new(exp)
                        .isolate_processes(workers, 1)
                        .wire_format(WireFormat::Json)
                        .run(&matrix)
                        .unwrap();
                    assert_eq!(r.len(), n);
                },
            )
            .clone();
        suite.note(format!(
            "{:.1}µs/task json wire vs {:.1}µs/task binary ({:.2}x)",
            json_wire.mean / n as f64 * 1e6,
            process.mean / n as f64 * 1e6,
            json_wire.mean / process.mean,
        ));
        extras.push((
            format!("ipc_dispatch_bin_{workers}w_{n}tasks"),
            Json::obj(vec![
                ("binary_us_per_task", Json::Num(process.mean / n as f64 * 1e6)),
                ("json_us_per_task", Json::Num(json_wire.mean / n as f64 * 1e6)),
                ("json_over_binary", Json::Num(json_wire.mean / process.mean)),
            ]),
        ));

        // TCP-remote tier: a standing pool with in-process worker threads
        // over loopback TCP. The pool (and its workers) persists across
        // the bench iterations — exactly the many-small-runs reuse story —
        // so this row measures framing + TCP round-trips + lease traffic,
        // not worker startup.
        use memento::ipc::pool::{PoolOptions, WorkerPool};
        use memento::ipc::transport::Transport;
        use memento::ipc::worker::{serve_remote, RemoteWorkerOptions};

        let token = "bench-token";
        let pool = WorkerPool::listen(
            &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
            PoolOptions { token: Some(token.to_string()), ..PoolOptions::default() },
        )
        .expect("bind bench pool");
        let worker_threads: Vec<_> = (0..workers)
            .map(|_| {
                let endpoint = pool.endpoint().clone();
                std::thread::spawn(move || {
                    let _ = serve_remote(
                        Arc::new(memento::prelude::Registry::solo(Arc::new(exp))),
                        &endpoint,
                        RemoteWorkerOptions {
                            token: Some(token.to_string()),
                            give_up_after: Some(std::time::Duration::from_secs(2)),
                            quiet: true,
                            ..RemoteWorkerOptions::default()
                        },
                    );
                })
            })
            .collect();
        let remote = suite
            .bench_with_setup(
                format!("{n} no-op tasks, {workers} tcp-remote workers"),
                1,
                3,
                || (),
                |_| {
                    let r = Memento::new(exp)
                        .with_worker_pool(Arc::clone(&pool))
                        .remote_workers("unused: pool owns the listener", workers)
                        .run(&matrix)
                        .unwrap();
                    assert_eq!(r.len(), n);
                },
            )
            .clone();
        let tcp_ratio = remote.mean / thread.mean;
        suite.note(format!(
            "{:.1}µs/task, {tcp_ratio:.1}x thread dispatch (standing pool, spawn amortized away)",
            remote.mean / n as f64 * 1e6
        ));
        extras.push((
            format!("ipc_dispatch_tcp_{workers}w_{n}tasks"),
            Json::obj(vec![
                ("thread_us_per_task", Json::Num(thread.mean / n as f64 * 1e6)),
                ("remote_us_per_task", Json::Num(remote.mean / n as f64 * 1e6)),
                ("remote_over_thread", Json::Num(tcp_ratio)),
                ("remote_over_process", Json::Num(remote.mean / process.mean)),
            ]),
        ));
        println!(
            "E7 tcp ({workers}w): dispatch {:.1}µs/task over a standing loopback pool",
            remote.mean / n as f64 * 1e6,
        );
        pool.shutdown();
        for t in worker_threads {
            let _ = t.join();
        }
    }

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
