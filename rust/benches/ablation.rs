//! Ablation bench: the design choices DESIGN.md calls out, each toggled in
//! isolation on the same 200-task / ~2ms-per-task workload.
//!
//! Dimensions:
//!   A1 cache off / on(no-fsync) / on(fsync)        — persistence cost
//!   A2 checkpoint off / every-1 / every-10 / every-100 — flush interval
//!   A3 task hashing: cost of SHA-256 identity (hash-only pass)
//!   A4 notification provider: none / memory / file
//!   A5 journal off / on

use memento::bench::Suite;
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::coordinator::notify::{FileNotificationProvider, MemoryNotificationProvider};
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

const N: usize = 200;

fn matrix() -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..N as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn work(_ctx: &memento::coordinator::task::TaskContext) -> Result<Json, memento::coordinator::error::MementoError> {
    std::thread::sleep(Duration::from_millis(2));
    Ok(Json::obj(vec![("score", Json::Num(0.5))]))
}

fn main() {
    let mut suite = Suite::new("ablations — coordinator design choices");
    let td = TempDir::new("bench-ablate").unwrap();
    let m = matrix();

    // --- A1: cache modes -----------------------------------------------------
    let base = suite
        .bench("A1 cache off", 1, 5, |_| {
            Memento::new(work).workers(4).run(&m).unwrap();
        })
        .clone();
    suite.note("baseline".to_string());

    let c_nosync = td.join("c-nosync");
    suite.bench_with_setup(
        "A1 cache on (no fsync, default)",
        0,
        5,
        || std::fs::remove_dir_all(&c_nosync).ok(),
        |_| {
            Memento::new(work)
                .workers(4)
                .with_cache_dir(&c_nosync)
                .run(&m)
                .unwrap();
        },
    );
    let last = suite.rows().last().unwrap().stats.mean;
    suite.note(format!("+{:.1}% over baseline", 100.0 * (last - base.mean) / base.mean));

    let c_sync = td.join("c-sync");
    suite.bench_with_setup(
        "A1 cache on (fsync)",
        0,
        5,
        || std::fs::remove_dir_all(&c_sync).ok(),
        |_| {
            let cache = Arc::new(ResultCache::open(&c_sync).unwrap().durable(true));
            Memento::new(work)
                .workers(4)
                .with_cache(cache)
                .run(&m)
                .unwrap();
        },
    );
    let last = suite.rows().last().unwrap().stats.mean;
    suite.note(format!("+{:.1}% over baseline", 100.0 * (last - base.mean) / base.mean));

    // --- A2: checkpoint flush interval ----------------------------------------
    for flush in [1usize, 10, 100] {
        let dir = td.join(&format!("ck-{flush}"));
        suite.bench_with_setup(
            format!("A2 checkpoint flush_every={flush}"),
            0,
            5,
            || std::fs::remove_dir_all(&dir).ok(),
            |_| {
                Memento::new(work)
                    .workers(4)
                    .with_checkpoint_dir(&dir)
                    .checkpoint_flush_every(flush)
                    .run(&m)
                    .unwrap();
            },
        );
        let last = suite.rows().last().unwrap().stats.mean;
        suite.note(format!("+{:.1}% over baseline", 100.0 * (last - base.mean) / base.mean));
    }

    // --- A3: hashing-only pass -------------------------------------------------
    suite.bench("A3 expansion+hash only (no exec)", 5, 50, |_| {
        for t in memento::coordinator::expand::Expansion::new(&m) {
            memento::bench::black_box(t.id("v1"));
        }
    });
    suite.note(format!("identity cost for {N} tasks"));

    // --- A4: notifiers ------------------------------------------------------------
    suite.bench("A4 notifier = memory", 1, 5, |_| {
        Memento::new(work)
            .workers(4)
            .with_notifier(Box::new(MemoryNotificationProvider::new()))
            .run(&m)
            .unwrap();
    });
    let nf = td.join("notify.jsonl");
    suite.bench("A4 notifier = file", 1, 5, |_| {
        Memento::new(work)
            .workers(4)
            .with_notifier(Box::new(FileNotificationProvider::new(&nf)))
            .run(&m)
            .unwrap();
    });

    // --- A5: journal ----------------------------------------------------------------
    let jf = td.join("journal.jsonl");
    suite.bench("A5 journal on", 1, 5, |_| {
        Memento::new(work)
            .workers(4)
            .with_journal(&jf)
            .run(&m)
            .unwrap();
    });
    let last = suite.rows().last().unwrap().stats.mean;
    suite.note(format!("+{:.1}% over baseline", 100.0 * (last - base.mean) / base.mean));

    suite.finish();
}
