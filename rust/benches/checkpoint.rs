//! Bench E4: checkpointing — "resumption without costly manual
//! intervention".
//!
//! Headline series: interrupt a 64-task run after k completions, resume,
//! and verify the resumed run re-executes exactly 64−k tasks; reports
//! resume overhead (manifest load + skip) and the manifest flush cost that
//! the running tasks pay.

use memento::bench::Suite;
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::checkpoint::CheckpointStore;
use memento::coordinator::memento::Memento;
use memento::coordinator::task::TaskId;
use memento::util::fs::TempDir;
use memento::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn tid(n: usize) -> TaskId {
    TaskId(format!("{n:064x}"))
}

/// Minimal recursive directory copy (bench-local helper).
fn copy_dir(src: &std::path::Path, dst: &std::path::Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        let to = dst.join(entry.file_name());
        if entry.path().is_dir() {
            copy_dir(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).unwrap();
        }
    }
}

fn main() {
    let mut suite = Suite::new("E4 — checkpoint & resume");
    let td = TempDir::new("bench-ckpt").unwrap();

    // --- micro: record/flush cost -------------------------------------------
    for flush_every in [1usize, 10, 100] {
        let dir = td.join(&format!("micro-{flush_every}"));
        let store = CheckpointStore::create(&dir, "fp", "v1", 10_000, flush_every).unwrap();
        let value = Json::obj(vec![("accuracy", Json::Num(0.93))]);
        let mut i = 0usize;
        let stats = suite
            .bench(
                format!("record (flush_every={flush_every})"),
                100,
                2000,
                |_| {
                    store.record(&tid(i), Some(&value), None, 0.1, 1).unwrap();
                    i += 1;
                },
            )
            .clone();
        suite.note(format!("{:.1}µs/task", stats.mean * 1e6));
    }

    // --- headline: interrupted run → resume ----------------------------------
    const N: usize = 64;
    let m64 = matrix(N);
    for k in [16usize, 32, 48] {
        let executions = Arc::new(AtomicUsize::new(0));
        let run_dir = td.join(&format!("resume-{k}"));

        // Phase 1: run that "crashes" (fails) every task after the first k.
        // Single worker makes the cutoff deterministic.
        {
            let ex = Arc::clone(&executions);
            let m = Memento::new(move |_ctx| {
                let n = ex.fetch_add(1, Ordering::SeqCst);
                if n < k {
                    // simulate ~1ms of work
                    std::thread::sleep(std::time::Duration::from_micros(300));
                    Ok(Json::int(n as i64))
                } else {
                    Err(memento::coordinator::error::MementoError::experiment(
                        "simulated crash",
                    ))
                }
            })
            .workers(1)
            .with_checkpoint_dir(&run_dir);
            let r = m.run(&m64).unwrap();
            assert_eq!(r.n_failed(), N - k);
        }

        // Snapshot the crashed run dir so every bench iteration resumes the
        // *same* partial manifest (a resume completes it, so it must be
        // restored before each timing).
        let snapshot = td.join(&format!("resume-{k}-snapshot"));
        copy_dir(&run_dir, &snapshot);

        // Phase 2: resume with healthy code; must re-run exactly N-k tasks.
        let resumed_execs = Arc::new(AtomicUsize::new(0));
        let re = Arc::clone(&resumed_execs);
        suite.bench_with_setup(
            format!("resume after {k}/{N} done"),
            1,
            10,
            || {
                let _ = std::fs::remove_dir_all(&run_dir);
                copy_dir(&snapshot, &run_dir);
                re.store(0, Ordering::SeqCst);
            },
            |_| {
                let re2 = Arc::clone(&resumed_execs);
                let m = Memento::new(move |_| {
                    re2.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_micros(300));
                    Ok(Json::int(0))
                })
                .workers(1)
                .with_checkpoint_dir(&run_dir);
                let r = m.resume(&m64).unwrap();
                assert_eq!(r.len(), N);
                assert_eq!(
                    resumed_execs.load(Ordering::SeqCst),
                    N - k,
                    "resume must re-run exactly the unfinished tasks"
                );
            },
        );
        suite.note(format!("re-ran exactly {}/{N} tasks each resume", N - k));
    }

    // --- resume overhead scaling with manifest size ---------------------------
    for n in [100usize, 1000, 5000] {
        let dir = td.join(&format!("load-{n}"));
        let store = CheckpointStore::create(&dir, "fp", "v1", n, 1000).unwrap();
        for i in 0..n {
            store
                .record(&tid(i), Some(&Json::int(i as i64)), None, 0.0, 1)
                .unwrap();
        }
        store.flush().unwrap();
        let stats = suite
            .bench(format!("manifest load ({n} entries)"), 3, 30, |_| {
                let s = CheckpointStore::resume(&dir, "fp", "v1", n, 1000).unwrap();
                assert_eq!(s.completed_count(), n);
            })
            .clone();
        suite.note(format!("{:.1}µs/entry", stats.mean / n as f64 * 1e6));
    }

    suite.finish();
}
