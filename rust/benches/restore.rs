//! Bench E8: restore throughput on resume-heavy runs.
//!
//! The planner's restore stage (cache probe + checkpoint record for
//! already-completed tasks) used to run *inside* the scheduler's source
//! mutex, so a resume of a mostly-complete run restored single-threaded
//! regardless of worker count. `DrainOnceSource` moved the filter outside
//! the lock (raw expansion is the only locked work); this bench records
//! restore throughput across worker counts so the before/after — and any
//! regression back to serialized restores — is visible in
//! `BENCH_sched_cache.json` as the `restore_<W>w_<N>tasks` rows.
//!
//! A fully warmed cache is the worst case for the old design (every spec
//! is filter work, zero execution) and the best showcase for the new one:
//! throughput should scale with workers until memory bandwidth, not stay
//! flat at the 1-worker line.

use memento::bench::{sched_cache_trajectory_path, Suite};
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::cache::ResultCache;
use memento::coordinator::memento::Memento;
use memento::util::json::Json;
use std::sync::Arc;

fn flat_matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn main() {
    let mut suite = Suite::new("E8 — restore throughput (mostly-cached resume)");
    let mut extras: Vec<(String, Json)> = Vec::new();

    let n = 20_000usize;
    let td = memento::util::fs::TempDir::new("bench-restore").unwrap();
    let matrix = flat_matrix(n);
    let cache = Arc::new(ResultCache::open(td.join("cache")).unwrap());

    // Warm the cache once; subsequent runs are 100% restores.
    let seeded = Memento::new(|_| Ok(Json::Null))
        .workers(8)
        .with_cache(Arc::clone(&cache))
        .run(&matrix)
        .unwrap();
    assert_eq!(seeded.len(), n);

    let mut single_worker_rate = 0.0f64;
    let mut bin_8w_mean = 0.0f64;
    for &workers in &[1usize, 2, 4, 8] {
        let cache2 = Arc::clone(&cache);
        let stats = suite
            .bench_with_setup(
                format!("restore {n} cached tasks, {workers}w"),
                1,
                5,
                || (),
                |_| {
                    let m = Memento::new(|_| Ok(Json::Null))
                        .workers(workers)
                        .with_cache(Arc::clone(&cache2));
                    let r = m.run(&matrix).unwrap();
                    assert_eq!(r.n_cached(), n, "resume must restore everything");
                },
            )
            .clone();
        let rate = n as f64 / stats.mean;
        if workers == 1 {
            single_worker_rate = rate;
        }
        if workers == 8 {
            bin_8w_mean = stats.mean;
        }
        let scaling = rate / single_worker_rate;
        suite.note(format!(
            "{:.2}µs/restore, {rate:.0}/s ({scaling:.2}x vs 1w)",
            stats.mean / n as f64 * 1e6
        ));
        extras.push((
            format!("restore_{workers}w_{n}tasks"),
            Json::obj(vec![
                ("restore_us_per_task", Json::Num(stats.mean / n as f64 * 1e6)),
                ("restores_per_sec", Json::Num(rate)),
                ("scaling_vs_1w", Json::Num(scaling)),
            ]),
        ));
        println!(
            "E8 headline ({workers}w): {rate:.0} restores/s ({scaling:.2}x vs 1 worker)"
        );
    }

    // Storage-codec delta on the restore path: the cache above holds
    // tagged-binary entries (the default) whose cold probes lazily scan
    // out just the "value" field; this one is an all-JSON store, the
    // shape every pre-codec cache directory has. Same 8-worker resume —
    // the difference is per-entry decode work inside the restore filter.
    let jcache = Arc::new(
        ResultCache::open(td.join("cache-json"))
            .unwrap()
            .storage_format(memento::util::codec::WireFormat::Json),
    );
    let seeded_json = Memento::new(|_| Ok(Json::Null))
        .workers(8)
        .with_cache(Arc::clone(&jcache))
        .run(&matrix)
        .unwrap();
    assert_eq!(seeded_json.len(), n);
    let json_stats = suite
        .bench_with_setup(
            format!("restore {n} cached tasks, 8w, json store"),
            1,
            5,
            || (),
            |_| {
                let m = Memento::new(|_| Ok(Json::Null))
                    .workers(8)
                    .with_cache(Arc::clone(&jcache));
                let r = m.run(&matrix).unwrap();
                assert_eq!(r.n_cached(), n, "resume must restore everything");
            },
        )
        .clone();
    suite.note(format!(
        "{:.2}µs/restore json store vs {:.2}µs binary ({:.2}x)",
        json_stats.mean / n as f64 * 1e6,
        bin_8w_mean / n as f64 * 1e6,
        json_stats.mean / bin_8w_mean,
    ));
    extras.push((
        format!("restore_scan_8w_{n}tasks"),
        Json::obj(vec![
            ("binary_us_per_task", Json::Num(bin_8w_mean / n as f64 * 1e6)),
            ("json_us_per_task", Json::Num(json_stats.mean / n as f64 * 1e6)),
            ("json_over_binary", Json::Num(json_stats.mean / bin_8w_mean)),
        ]),
    ));

    suite.write_trajectory(&sched_cache_trajectory_path(), extras);
    suite.finish();
}
