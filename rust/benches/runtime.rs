//! Bench E8: the PJRT hot path — latency/throughput of the AOT-compiled
//! JAX/Pallas executables driven from Rust.
//!
//! Requires `make artifacts`. Reports compile time (one-off), train-step
//! and predict latency, steps/s, and the effective FLOP rate of the MLP's
//! dense kernels.

use memento::bench::Suite;
use memento::runtime::artifact::shared_store;
use memento::runtime::tensor::Tensor;
use memento::util::rng::Rng;

fn main() {
    let store = match shared_store() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("E8 skipped: {e}\nrun `make artifacts` first");
            std::process::exit(0);
        }
    };
    let meta = store.meta;
    let mut suite = Suite::new("E8 — PJRT runtime hot path");

    // --- one-off compile cost ------------------------------------------------
    let t0 = std::time::Instant::now();
    let step = store.executable("mlp_train_step").unwrap();
    let compile_train = t0.elapsed();
    let t0 = std::time::Instant::now();
    let predict = store.executable("mlp_predict").unwrap();
    let compile_pred = t0.elapsed();
    println!(
        "compile (one-off): train_step {} | predict {}",
        memento::util::time::fmt_duration(compile_train),
        memento::util::time::fmt_duration(compile_pred)
    );

    // --- inputs ----------------------------------------------------------------
    let mut rng = Rng::new(0);
    let mut randn = |shape: Vec<usize>, scale: f64| {
        let n: usize = shape.iter().product();
        Tensor::new(
            shape,
            (0..n).map(|_| (rng.normal() * scale) as f32).collect(),
        )
    };
    let mut w1 = randn(vec![meta.features, meta.hidden], 0.18);
    let mut b1 = Tensor::zeros(vec![meta.hidden]);
    let mut w2 = randn(vec![meta.hidden, meta.classes], 0.25);
    let mut b2 = Tensor::zeros(vec![meta.classes]);
    let x = randn(vec![meta.batch, meta.features], 1.0);
    let mut y = vec![0f32; meta.batch * meta.classes];
    for i in 0..meta.batch {
        y[i * meta.classes + i % 3] = 1.0;
    }
    let y = Tensor::new(vec![meta.batch, meta.classes], y);
    let mask = Tensor::new(vec![meta.classes], {
        let mut v = vec![0f32; meta.classes];
        v[..3].fill(1.0);
        v
    });
    let lr = Tensor::scalar(0.1);

    // --- train-step latency -----------------------------------------------------
    let stats = suite
        .bench("mlp_train_step (batch 128)", 20, 300, |_| {
            let out = step.run(&[&w1, &b1, &w2, &b2, &x, &y, &mask, &lr]).unwrap();
            let mut it = out.into_iter();
            w1 = it.next().unwrap();
            b1 = it.next().unwrap();
            w2 = it.next().unwrap();
            b2 = it.next().unwrap();
            let loss = it.next().unwrap().data[0];
            assert!(loss.is_finite());
        })
        .clone();
    // FLOPs: fwd 2*(B*F*H + B*H*C) ; bwd ≈ 2x fwd (dx, dw matmuls).
    let fwd_flops = 2.0 * (meta.batch * meta.features * meta.hidden
        + meta.batch * meta.hidden * meta.classes) as f64;
    let step_flops = 3.0 * fwd_flops;
    suite.note(format!(
        "{:.0} steps/s, ~{:.2} GFLOP/s",
        1.0 / stats.mean,
        step_flops / stats.mean / 1e9
    ));

    // --- predict latency ----------------------------------------------------------
    let stats = suite
        .bench("mlp_predict (batch 128)", 20, 300, |_| {
            let out = predict.run(&[&w1, &b1, &w2, &b2, &x, &mask]).unwrap();
            assert_eq!(out[0].shape, vec![meta.batch, meta.classes]);
        })
        .clone();
    suite.note(format!(
        "{:.0} batches/s ({:.0} rows/s)",
        1.0 / stats.mean,
        meta.batch as f64 / stats.mean
    ));

    // --- tensor marshalling cost (host <-> literal) ---------------------------------
    let big = randn(vec![meta.batch, meta.features], 1.0);
    suite.bench("tensor→literal (128×64 f32)", 100, 2000, |_| {
        memento::bench::black_box(big.to_literal().unwrap());
    });

    suite.finish();
}
