//! Bench E2: parallelization speedup (Fig. 1 / §2 claim: "significantly
//! reducing the time required for large-scale experiments").
//!
//! Testbed note (recorded in EXPERIMENTS.md): this image exposes exactly
//! ONE physical CPU, so CPU-bound tasks cannot speed up — the bench
//! therefore runs two series:
//!
//! 1. **wait-bound tasks** (50 ms sleep + small compute), modelling
//!    experiments that block on I/O, GPUs, or remote resources: the
//!    coordinator must deliver near-linear wall-clock scaling in the
//!    worker count — this isolates the *coordinator's* scaling behaviour,
//!    which is what the paper claims;
//! 2. **CPU-bound tasks**, reported honestly as the 1-core roofline
//!    (speedup ≈ 1.0x, overhead < a few %).

use memento::bench::Suite;
use memento::config::matrix::ConfigMatrix;
use memento::config::value::pv_int;
use memento::coordinator::memento::Memento;
use memento::util::json::Json;
use std::time::Duration;

fn matrix(n: usize) -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("i", (0..n as i64).map(pv_int).collect())
        .build()
        .unwrap()
}

fn cpu_work(iters: u64) -> u64 {
    let mut x = 1u64;
    for i in 0..iters {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(x)
}

fn main() {
    let mut suite = Suite::new("E2 — parallel speedup");
    const N_TASKS: usize = 32;
    let m = matrix(N_TASKS);

    // --- series 1: wait-bound (the paper's long-experiment regime) ---------
    println!("\nseries 1: {N_TASKS} wait-bound tasks (50ms each, ideal serial = 1.6s)");
    let mut serial_mean = 0.0;
    for &workers in &[1usize, 2, 4, 8, 16] {
        let stats = suite
            .bench(format!("wait-bound, {workers:>2} workers"), 1, 5, |_| {
                let r = Memento::new(|_| {
                    std::thread::sleep(Duration::from_millis(50));
                    cpu_work(10_000);
                    Ok(Json::Null)
                })
                .workers(workers)
                .run(&m)
                .unwrap();
                assert_eq!(r.len(), N_TASKS);
            })
            .clone();
        if workers == 1 {
            serial_mean = stats.mean;
        }
        let speedup = serial_mean / stats.mean;
        let ideal = workers.min(N_TASKS) as f64;
        suite.note(format!(
            "speedup {speedup:.2}x (ideal {ideal:.0}x, efficiency {:.0}%)",
            100.0 * speedup / ideal
        ));
    }

    // --- series 2: CPU-bound (honest 1-core roofline) ----------------------
    println!("\nseries 2: {N_TASKS} CPU-bound tasks (~20ms each) — single-core image");
    let mut serial_mean = 0.0;
    for &workers in &[1usize, 4] {
        let stats = suite
            .bench(format!("cpu-bound, {workers:>2} workers"), 1, 5, |_| {
                let r = Memento::new(|_| {
                    cpu_work(20_000_000);
                    Ok(Json::Null)
                })
                .workers(workers)
                .run(&m)
                .unwrap();
                assert_eq!(r.len(), N_TASKS);
            })
            .clone();
        if workers == 1 {
            serial_mean = stats.mean;
        }
        suite.note(format!(
            "speedup {:.2}x (1-core roofline: 1.0x; multi-worker overhead {:+.1}%)",
            serial_mean / stats.mean,
            100.0 * (stats.mean - serial_mean) / serial_mean
        ));
    }

    suite.finish();
    println!(
        "E2 shape check: wait-bound speedup should track the worker count up to \
         min(workers, tasks); cpu-bound stays ≈1.0x on this 1-core testbed."
    );
}
