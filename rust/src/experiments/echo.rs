//! A tiny built-in experiment: parameters in, parameters + deterministic
//! content hash out.
//!
//! `echo` exists so tests, CI, and capability-negotiation scenarios can
//! exercise the full pipeline — hashing, caching, checkpointing, all three
//! backends, and the registry's named dispatch — without touching the ML
//! grid. It accepts **any** parameter assignment; an optional `sleep_ms`
//! parameter (or run-wide setting) makes task durations controllable for
//! scheduler tests.

use crate::coordinator::error::MementoError;
use crate::coordinator::task::{sha256_hex, TaskContext};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Version of the built-in `echo` experiment — the id-hash salt of its
/// named tasks (see [`crate::coordinator::task::TaskSpec::id`]).
pub const ECHO_VERSION: &str = "v1";

/// The `echo` experiment function: returns `{params, hash}` where `hash`
/// is the SHA-256 of the canonical JSON of the parameter assignment —
/// deterministic across runs, machines, and backends.
pub fn echo_exp_fn(
) -> impl Fn(&TaskContext) -> Result<Json, MementoError> + Send + Sync + 'static {
    |ctx: &TaskContext| {
        let sleep_ms = ctx
            .spec
            .get("sleep_ms")
            .and_then(|v| v.as_i64())
            .or_else(|| ctx.setting("sleep_ms").and_then(|j| j.as_i64()))
            .unwrap_or(0);
        if sleep_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(sleep_ms as u64));
        }
        let params = Json::Obj(
            ctx.spec
                .params
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect::<BTreeMap<_, _>>(),
        );
        let hash = sha256_hex(params.canonical().as_bytes());
        Ok(Json::obj(vec![("hash", Json::str(hash)), ("params", params)]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};
    use crate::coordinator::task::{TaskContext, TaskSpec};
    use std::sync::Arc;

    fn run_echo(params: Vec<(String, crate::config::value::ParamValue)>) -> Json {
        let spec = TaskSpec { params, index: 0, exp: None };
        let id = spec.id("v1");
        let ctx = TaskContext::new(
            spec,
            Arc::new(BTreeMap::new()),
            0,
            1,
            id,
            None,
            None,
        );
        echo_exp_fn()(&ctx).unwrap()
    }

    #[test]
    fn hash_is_deterministic_and_param_sensitive() {
        let a = run_echo(vec![("x".into(), pv_int(1)), ("y".into(), pv_str("q"))]);
        let b = run_echo(vec![("y".into(), pv_str("q")), ("x".into(), pv_int(1))]);
        // Canonical hashing: declaration order must not matter.
        assert_eq!(a.get("hash"), b.get("hash"));
        let c = run_echo(vec![("x".into(), pv_int(2)), ("y".into(), pv_str("q"))]);
        assert_ne!(a.get("hash"), c.get("hash"));
        assert_eq!(a.get("hash").and_then(|h| h.as_str()).unwrap().len(), 64);
        assert_eq!(
            a.get("params").and_then(|p| p.get("x")).and_then(|v| v.as_i64()),
            Some(1)
        );
    }

    #[test]
    fn sleep_ms_param_is_honored() {
        let t0 = std::time::Instant::now();
        let out = run_echo(vec![("sleep_ms".into(), pv_int(20))]);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(20));
        // sleep_ms participates in the echoed params like any other.
        assert_eq!(
            out.get("params").and_then(|p| p.get("sleep_ms")).and_then(|v| v.as_i64()),
            Some(20)
        );
    }
}
