//! The paper's §3 demonstration grid as a reusable workload.
//!
//! Examples, integration tests, and benches all run *this* — the exact
//! configuration matrix from the paper (3 datasets × 2 imputers × 3
//! preprocessors × 3 models = 54 combinations, minus the
//! `digits × SimpleImputer` exclusion = 45 tasks), plus an extended variant
//! that adds the AOT/PJRT-backed `MLP` as a fourth model family so the
//! end-to-end driver exercises all three layers.

use crate::config::matrix::ConfigMatrix;
use crate::config::value::pv_str;
use crate::coordinator::error::MementoError;
use crate::coordinator::task::TaskContext;
use crate::ml::dataset::load_by_name;
use crate::ml::impute::imputer_by_name;
use crate::ml::pipeline::{cross_validate, model_by_name};
use crate::ml::scale::scaler_by_name;
use crate::ml::tree::Classifier;
use crate::runtime::artifact::ArtifactStore;
use crate::runtime::mlp::{MlpModel, MlpParams};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Version of the built-in `grid` experiment as registered under its name
/// — the id-hash salt of tasks that *name* `grid` (unnamed CLI runs keep
/// salting with the run-wide `--version` instead, preserving pre-registry
/// task ids).
pub const GRID_VERSION: &str = "v1";

/// The exact §3 matrix: 3×2×3×3 = 54 raw, 45 after exclusion.
pub fn paper_matrix() -> ConfigMatrix {
    base_builder(vec!["AdaBoost", "RandomForest", "SVC"])
        .build()
        .expect("paper matrix is valid")
}

/// §3 matrix + the AOT MLP model family: 3×2×3×4 = 72 raw, 60 after
/// exclusion. This is the end-to-end driver's workload.
pub fn extended_matrix() -> ConfigMatrix {
    base_builder(vec!["AdaBoost", "RandomForest", "SVC", "MLP"])
        .build()
        .expect("extended matrix is valid")
}

/// A fast variant on the tiny `toy` dataset (for tests and micro-benches).
pub fn toy_matrix() -> ConfigMatrix {
    ConfigMatrix::builder()
        .param("dataset", vec![pv_str("toy")])
        .param(
            "feature_engineering",
            vec![pv_str("DummyImputer"), pv_str("SimpleImputer")],
        )
        .param(
            "preprocessing",
            vec![pv_str("DummyPreprocessor"), pv_str("StandardScaler")],
        )
        .param("model", vec![pv_str("SVC"), pv_str("DecisionTree")])
        .setting("n_fold", Json::int(3))
        .setting("data_seed", Json::int(0))
        .build()
        .expect("toy matrix is valid")
}

fn base_builder(models: Vec<&str>) -> crate::config::matrix::MatrixBuilder {
    ConfigMatrix::builder()
        .param(
            "dataset",
            vec![pv_str("digits"), pv_str("wine"), pv_str("breast_cancer")],
        )
        .param(
            "feature_engineering",
            vec![pv_str("DummyImputer"), pv_str("SimpleImputer")],
        )
        .param(
            "preprocessing",
            vec![
                pv_str("DummyPreprocessor"),
                pv_str("MinMaxScaler"),
                pv_str("StandardScaler"),
            ],
        )
        .param("model", models.into_iter().map(pv_str).collect())
        .setting("n_fold", Json::int(5))
        .setting("data_seed", Json::int(0))
        .exclude(vec![
            ("dataset", pv_str("digits")),
            ("feature_engineering", pv_str("SimpleImputer")),
        ])
}

/// The experiment function for the grid (the paper's `exp_func`).
///
/// Reads `dataset` / `feature_engineering` / `preprocessing` / `model` from
/// the task, `n_fold` and `data_seed` from the settings, runs k-fold CV,
/// and returns `{accuracy, macro_f1, folds, n_eval}`. The `MLP` model is
/// dispatched to the PJRT runtime through `store`.
pub fn grid_exp_fn(
    store: Option<Arc<ArtifactStore>>,
) -> impl Fn(&TaskContext) -> Result<Json, MementoError> + Send + Sync + 'static {
    move |ctx: &TaskContext| {
        let dataset_name = ctx.param_str("dataset")?;
        let fe = ctx.param_str("feature_engineering")?;
        let prep = ctx.param_str("preprocessing")?;
        let model_name = ctx.param_str("model")?;
        let n_fold = ctx.setting_i64("n_fold", 5) as usize;
        let data_seed = ctx.setting_i64("data_seed", 0) as u64;

        let ds = load_by_name(dataset_name, data_seed).ok_or_else(|| {
            MementoError::experiment(format!("unknown dataset '{dataset_name}'"))
        })?;
        // Fail fast on bad stage names (validated here so errors carry task context).
        imputer_by_name(fe)
            .ok_or_else(|| MementoError::experiment(format!("unknown imputer '{fe}'")))?;
        scaler_by_name(prep)
            .ok_or_else(|| MementoError::experiment(format!("unknown scaler '{prep}'")))?;

        let mut rng = Rng::new(ctx.seed);
        let factory: Box<dyn Fn() -> Box<dyn Classifier>> = if model_name == "MLP" {
            let store = store
                .clone()
                .ok_or_else(|| {
                    MementoError::experiment(
                        "model 'MLP' requires the AOT artifact store (run `make artifacts`)",
                    )
                })?;
            Box::new(move || {
                Box::new(MlpModel::new(Arc::clone(&store), MlpParams::default()))
                    as Box<dyn Classifier>
            })
        } else {
            let name = model_name.to_string();
            model_by_name(&name).ok_or_else(|| {
                MementoError::experiment(format!("unknown model '{name}'"))
            })?;
            Box::new(move || model_by_name(&name).unwrap())
        };

        let scores = cross_validate(&ds, fe, prep, &*factory, n_fold, &mut rng)
            .map_err(|e| MementoError::experiment(e.to_string()))?;

        Ok(Json::obj(vec![
            ("accuracy", Json::Num(scores.mean_accuracy)),
            ("macro_f1", Json::Num(scores.mean_macro_f1)),
            (
                "folds",
                Json::Arr(scores.fold_accuracy.iter().map(|&a| Json::Num(a)).collect()),
            ),
            ("n_eval", Json::int(scores.n_eval as i64)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::expand;
    use crate::coordinator::memento::Memento;

    #[test]
    fn paper_matrix_counts() {
        // E1: the §3 worked example.
        let m = paper_matrix();
        assert_eq!(m.raw_count(), 54);
        assert_eq!(expand::count_included(&m), 45);
        let e = extended_matrix();
        assert_eq!(e.raw_count(), 72);
        assert_eq!(expand::count_included(&e), 60);
    }

    #[test]
    fn toy_grid_runs_end_to_end_without_runtime() {
        let results = Memento::new(grid_exp_fn(None))
            .workers(4)
            .seed(1)
            .run(&toy_matrix())
            .unwrap();
        assert_eq!(results.len(), 8);
        assert_eq!(results.n_failed(), 0);
        for o in results.iter() {
            let acc = o.metric("accuracy").unwrap();
            assert!(acc > 0.5, "task {} acc {acc}", o.spec.label());
            assert!(o.metric("macro_f1").unwrap() > 0.3);
        }
    }

    #[test]
    fn mlp_without_store_is_clean_failure() {
        let m = ConfigMatrix::builder()
            .param("dataset", vec![pv_str("toy")])
            .param("feature_engineering", vec![pv_str("DummyImputer")])
            .param("preprocessing", vec![pv_str("DummyPreprocessor")])
            .param("model", vec![pv_str("MLP")])
            .build()
            .unwrap();
        let results = Memento::new(grid_exp_fn(None)).run(&m).unwrap();
        assert_eq!(results.n_failed(), 1);
        let f = results.failures().next().unwrap().failure.clone().unwrap();
        assert!(f.message.contains("make artifacts"), "{}", f.message);
    }

    #[test]
    fn unknown_dataset_is_task_failure_not_crash() {
        let m = ConfigMatrix::builder()
            .param("dataset", vec![pv_str("imagenet")])
            .param("feature_engineering", vec![pv_str("DummyImputer")])
            .param("preprocessing", vec![pv_str("DummyPreprocessor")])
            .param("model", vec![pv_str("SVC")])
            .build()
            .unwrap();
        let results = Memento::new(grid_exp_fn(None)).run(&m).unwrap();
        assert_eq!(results.n_failed(), 1);
    }
}
