//! Reusable experiment workloads: the paper's §3 demonstration grid wired
//! as a library so examples, tests, and benches share one definition.

pub mod grid;
