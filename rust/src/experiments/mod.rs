//! Reusable experiment workloads and the named experiment registry.
//!
//! [`grid`] is the paper's §3 demonstration grid wired as a library so
//! examples, tests, and benches share one definition; [`echo`] is the tiny
//! smoke workload; [`registry`] maps experiment *names* to functions so a
//! task — not a process — decides what it runs.

pub mod echo;
pub mod grid;
pub mod registry;
