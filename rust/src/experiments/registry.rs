//! The named experiment registry: decouples *which experiment runs* from
//! *which binary runs it*.
//!
//! Pre-registry, a process embedded exactly one [`ExpFn`] and every task
//! implicitly meant "that function". The registry maps experiment **names**
//! to [`ExpEntry`]s (function + version + description), so a *task* — via
//! [`crate::coordinator::task::TaskSpec::exp`] — decides what it runs:
//!
//! - A run built with [`crate::coordinator::memento::Memento::with_registry`]
//!   can mix experiments in one matrix (a reserved `exp` row parameter or a
//!   run-level `.exp(name)` selection picks the entry per task).
//! - A v5 worker advertises its registered names in its `Ready` handshake,
//!   and the supervisor dispatches a named task only to a worker that
//!   registered that name (see [`crate::ipc::supervisor`]).
//! - Each entry carries its **own version** used as that experiment's
//!   id-hash salt: bumping one entry's version invalidates only its cached
//!   results, never a co-registered experiment's.
//!
//! The **fallback** entry preserves the pre-registry world: an unnamed task
//! (`exp == None`) resolves to it, hashes with the run-wide version, and
//! produces byte-identical task ids to older versions — which is why
//! pre-registry caches, checkpoints, and stores restore with zero
//! executions. [`Registry::solo`] (what `Memento::new` builds) is nothing
//! but a fallback.

use crate::coordinator::error::MementoError;
use crate::coordinator::memento::ExpFn;
use crate::coordinator::task::ExpRef;
use crate::experiments::echo::{echo_exp_fn, ECHO_VERSION};
use crate::experiments::grid::{grid_exp_fn, GRID_VERSION};
use crate::runtime::artifact::ArtifactStore;
use std::collections::BTreeMap;
use std::sync::Arc;

/// One registered experiment: its function, version, and a one-line
/// description for `memento exps`.
#[derive(Clone)]
pub struct ExpEntry {
    /// The experiment function executed for tasks naming this entry.
    pub exp_fn: Arc<ExpFn>,
    /// This experiment's version — the id-hash salt of its named tasks.
    /// Bumping it invalidates this experiment's cached results only.
    pub version: String,
    /// Human-readable summary shown by `memento exps`.
    pub description: String,
}

impl std::fmt::Debug for ExpEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExpEntry")
            .field("version", &self.version)
            .field("description", &self.description)
            .finish_non_exhaustive()
    }
}

/// A name → experiment mapping plus an optional unnamed fallback (the
/// pre-registry implicit single experiment). See the module docs.
#[derive(Clone, Default)]
pub struct Registry {
    entries: BTreeMap<String, ExpEntry>,
    fallback: Option<Arc<ExpFn>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("entries", &self.entries)
            .field("fallback", &self.fallback.is_some())
            .finish()
    }
}

impl Registry {
    /// An empty registry (no entries, no fallback).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A registry holding nothing but the unnamed fallback — the
    /// pre-registry single-experiment world. This is what
    /// [`crate::coordinator::memento::Memento::new`] builds, so existing
    /// call sites keep their exact behavior (and task ids).
    pub fn solo(exp_fn: Arc<ExpFn>) -> Registry {
        Registry { entries: BTreeMap::new(), fallback: Some(exp_fn) }
    }

    /// Registers a named experiment (builder-style). Re-registering a name
    /// replaces the previous entry.
    pub fn register(
        mut self,
        name: impl Into<String>,
        version: impl Into<String>,
        description: impl Into<String>,
        exp_fn: impl Fn(&crate::coordinator::task::TaskContext) -> Result<crate::util::json::Json, MementoError>
            + Send
            + Sync
            + 'static,
    ) -> Self {
        self.entries.insert(
            name.into(),
            ExpEntry {
                exp_fn: Arc::new(exp_fn),
                version: version.into(),
                description: description.into(),
            },
        );
        self
    }

    /// Sets the unnamed fallback: the function unnamed (`exp == None`)
    /// tasks resolve to, hashing with the run-wide version exactly as
    /// pre-registry versions did.
    pub fn register_default(
        mut self,
        exp_fn: impl Fn(&crate::coordinator::task::TaskContext) -> Result<crate::util::json::Json, MementoError>
            + Send
            + Sync
            + 'static,
    ) -> Self {
        self.fallback = Some(Arc::new(exp_fn));
        self
    }

    /// The built-in registry backing the CLI: the §3 `grid` (also the
    /// unnamed fallback, so `memento run` without `--exp` keeps producing
    /// pre-registry task ids and restores existing caches) and the `echo`
    /// smoke experiment.
    pub fn builtin(store: Option<Arc<ArtifactStore>>) -> Registry {
        let grid: Arc<ExpFn> = Arc::new(grid_exp_fn(store));
        let fallback = Arc::clone(&grid);
        let mut entries = BTreeMap::new();
        entries.insert(
            "grid".to_string(),
            ExpEntry {
                exp_fn: grid,
                version: GRID_VERSION.to_string(),
                description: "paper §3 ML grid: k-fold CV over dataset × imputer × \
                              preprocessor × model"
                    .to_string(),
            },
        );
        entries.insert(
            "echo".to_string(),
            ExpEntry {
                exp_fn: Arc::new(echo_exp_fn()),
                version: ECHO_VERSION.to_string(),
                description: "params in → params + deterministic hash out (optional \
                              sleep_ms); the smoke/CI workload"
                    .to_string(),
            },
        );
        Registry { entries, fallback: Some(fallback) }
    }

    /// Registered experiment names, sorted (what a v5 worker advertises in
    /// its `Ready` handshake).
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// The entry registered under `name`, if any.
    pub fn get(&self, name: &str) -> Option<&ExpEntry> {
        self.entries.get(name)
    }

    /// Iterates registered `(name, entry)` pairs in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &ExpEntry)> {
        self.entries.iter()
    }

    /// Number of named entries (the fallback does not count).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is registered — no names and no fallback.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.fallback.is_none()
    }

    /// True when an unnamed fallback is installed.
    pub fn has_fallback(&self) -> bool {
        self.fallback.is_some()
    }

    /// Name → version of every named entry (recorded in checkpoint
    /// manifests so a resume can detect a version drift per experiment).
    pub fn versions(&self) -> BTreeMap<String, String> {
        self.entries
            .iter()
            .map(|(n, e)| (n.clone(), e.version.clone()))
            .collect()
    }

    /// The [`ExpRef`] for a registered name, if present.
    pub fn ref_for(&self, name: &str) -> Option<ExpRef> {
        self.entries
            .get(name)
            .map(|e| ExpRef { name: name.to_string(), version: e.version.clone() })
    }

    /// The reference unnamed specs acquire by default: `None` while a
    /// fallback exists (they stay unnamed and keep legacy hashing); the
    /// sole entry's reference when exactly one experiment is registered
    /// without a fallback; otherwise `None` (resolution then fails with a
    /// clear error at dispatch).
    pub fn default_ref(&self) -> Option<ExpRef> {
        if self.fallback.is_some() || self.entries.len() != 1 {
            return None;
        }
        let (name, entry) = self.entries.iter().next().expect("len checked");
        Some(ExpRef { name: name.clone(), version: entry.version.clone() })
    }

    /// Resolves a task's experiment reference to its function. `None`
    /// resolves to the fallback (or the sole named entry); an unknown name
    /// is an [`MementoError::Experiment`] whose message lists what *is*
    /// registered — the message surfaced by `unknown-experiment` task
    /// failures.
    pub fn resolve(&self, exp: Option<&ExpRef>) -> Result<Arc<ExpFn>, MementoError> {
        match exp {
            Some(e) => self
                .entries
                .get(&e.name)
                .map(|entry| Arc::clone(&entry.exp_fn))
                .ok_or_else(|| {
                    MementoError::experiment(format!(
                        "unknown experiment '{}' (registered: {})",
                        e.name,
                        self.describe_names()
                    ))
                }),
            None => {
                if let Some(f) = &self.fallback {
                    return Ok(Arc::clone(f));
                }
                if self.entries.len() == 1 {
                    let entry = self.entries.values().next().expect("len checked");
                    return Ok(Arc::clone(&entry.exp_fn));
                }
                Err(MementoError::experiment(format!(
                    "task names no experiment and the registry has no fallback \
                     (registered: {})",
                    self.describe_names()
                )))
            }
        }
    }

    /// A registry restricted to `names` (plus the fallback, which serves
    /// only unnamed tasks) — what `memento serve --exps a,b` builds so a
    /// standing worker advertises and serves a subset of its binary's
    /// experiments. Unknown names are a config error.
    pub fn subset(&self, names: &[String]) -> Result<Registry, MementoError> {
        let mut entries = BTreeMap::new();
        for name in names {
            let entry = self.entries.get(name).ok_or_else(|| {
                MementoError::config(format!(
                    "--exps names unknown experiment '{name}' (registered: {})",
                    self.describe_names()
                ))
            })?;
            entries.insert(name.clone(), entry.clone());
        }
        Ok(Registry { entries, fallback: self.fallback.clone() })
    }

    /// Annotates a freshly expanded spec with its resolved [`ExpRef`] —
    /// the one place the "which experiment is this task?" precedence
    /// lives, shared by the run pipeline and `memento expand`:
    ///
    /// 1. the row's reserved `exp` parameter, else
    /// 2. the run-level selection (`Memento::exp` / `--exp`), else
    /// 3. [`Registry::default_ref`] (unnamed while a fallback exists — the
    ///    pre-registry hash-compatible path).
    ///
    /// An unknown name is carried through salted with the run version so
    /// dispatch can fail it as a typed unknown-experiment failure instead
    /// of silently running other code against it.
    pub fn annotate_spec(
        &self,
        mut spec: crate::coordinator::task::TaskSpec,
        run_exp: Option<&str>,
        run_version: &str,
    ) -> crate::coordinator::task::TaskSpec {
        let chosen = spec
            .get("exp")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .or_else(|| run_exp.map(|s| s.to_string()));
        spec.exp = match chosen {
            Some(name) => Some(match self.ref_for(&name) {
                Some(r) => r,
                None => ExpRef { name, version: run_version.to_string() },
            }),
            None => self.default_ref(),
        };
        spec
    }

    fn describe_names(&self) -> String {
        if self.entries.is_empty() {
            "none".to_string()
        } else {
            self.names().join(", ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::pv_int;
    use crate::coordinator::task::{TaskContext, TaskSpec};
    use crate::util::json::Json;

    fn ctx() -> TaskContext {
        let spec = TaskSpec {
            params: vec![("x".into(), pv_int(7))],
            index: 0,
            exp: None,
        };
        let id = spec.id("v1");
        TaskContext::new(
            spec,
            Arc::new(BTreeMap::new()),
            0,
            1,
            id,
            None,
            None,
        )
    }

    #[test]
    fn builtin_registers_grid_and_echo_with_fallback() {
        let r = Registry::builtin(None);
        assert_eq!(r.names(), vec!["echo".to_string(), "grid".to_string()]);
        assert!(r.has_fallback());
        assert_eq!(r.len(), 2);
        assert!(!r.is_empty());
        // Unnamed tasks keep resolving (to the grid fallback).
        assert!(r.resolve(None).is_ok());
        let echo = r.ref_for("echo").unwrap();
        assert_eq!(echo.version, ECHO_VERSION);
        let f = r.resolve(Some(&echo)).unwrap();
        assert!(f(&ctx()).unwrap().get("hash").is_some());
    }

    #[test]
    fn unknown_name_error_lists_registered() {
        let r = Registry::builtin(None);
        let bad = ExpRef { name: "nope".into(), version: "v1".into() };
        let err = r.resolve(Some(&bad)).unwrap_err().to_string();
        assert!(err.contains("unknown experiment 'nope'"), "{err}");
        assert!(err.contains("echo, grid"), "{err}");
    }

    #[test]
    fn solo_is_fallback_only() {
        let r = Registry::solo(Arc::new(|_: &TaskContext| Ok(Json::int(1))));
        assert!(r.names().is_empty());
        assert!(r.has_fallback());
        assert!(!r.is_empty());
        assert!(r.resolve(None).is_ok());
        assert!(r.default_ref().is_none(), "solo tasks stay unnamed");
    }

    #[test]
    fn single_entry_without_fallback_auto_resolves() {
        let r = Registry::new().register("only", "v9", "the one", |_| Ok(Json::int(2)));
        let d = r.default_ref().unwrap();
        assert_eq!(d.name, "only");
        assert_eq!(d.version, "v9");
        assert!(r.resolve(None).is_ok());
        // Two entries and no fallback: unnamed resolution must fail.
        let r2 = r.register("other", "v1", "another", |_| Ok(Json::int(3)));
        assert!(r2.default_ref().is_none());
        assert!(r2.resolve(None).is_err());
    }

    #[test]
    fn subset_restricts_names_and_rejects_unknown() {
        let r = Registry::builtin(None);
        let s = r.subset(&["echo".to_string()]).unwrap();
        assert_eq!(s.names(), vec!["echo".to_string()]);
        assert!(s.has_fallback(), "fallback still serves unnamed tasks");
        assert!(s.resolve(Some(&ExpRef { name: "grid".into(), version: "v1".into() })).is_err());
        assert!(r.subset(&["mystery".to_string()]).is_err());
    }

    #[test]
    fn versions_map_names_entry_versions() {
        let v = Registry::builtin(None).versions();
        assert_eq!(v.get("echo").map(String::as_str), Some(ECHO_VERSION));
        assert_eq!(v.get("grid").map(String::as_str), Some(GRID_VERSION));
    }
}
