//! Minimal-dependency JSON implementation.
//!
//! The offline build image ships only the `xla` crate's dependency closure,
//! so `serde`/`serde_json` are unavailable; Memento persists its config
//! matrices, cache entries, checkpoints, and artifact manifests through this
//! module instead.
//!
//! Provides:
//! - [`Json`] — an owned JSON value tree,
//! - [`parse`] — a recursive-descent parser with line/column errors,
//! - compact ([`Json::to_string`]) and pretty ([`Json::pretty`]) writers,
//! - a *canonical* writer ([`Json::canonical`]) with sorted object keys and
//!   a fixed number format, used for stable task hashing.

use std::collections::BTreeMap;
use std::fmt;

/// An owned JSON value.
///
/// Objects use a `BTreeMap` so iteration (and therefore serialization) order
/// is deterministic — a requirement for content-addressed task hashing.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The JSON `null` literal.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number (JSON has one numeric type; integers ride in `f64`,
    /// exact for |n| < 2⁵³).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, ordered by key for deterministic serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Builds an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builds an array.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number constructor.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Integer convenience constructor (goes through `f64`; exact for |n| < 2^53).
    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }

    /// Boolean constructor.
    pub fn bool(b: bool) -> Json {
        Json::Bool(b)
    }

    // ---- accessors ------------------------------------------------------

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an integer, if this is a `Num` holding one exactly.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// The value as a non-negative integer index.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| if v >= 0 { Some(v as usize) } else { None })
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(idx))
    }

    /// True for the `Null` literal.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- writers --------------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::with_capacity(64);
        self.write(&mut out);
        out
    }

    /// Canonical serialization: identical to [`Json::to_string`] (object keys
    /// are already sorted by the `BTreeMap`), but numbers that are exact
    /// integers are written without a fractional part so `1`, `1.0` hash the
    /// same. Used for task identity.
    pub fn canonical(&self) -> String {
        self.to_string()
    }

    /// Pretty-printed serialization with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        const PAD: &str = "  ";
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    for _ in 0..=indent {
                        out.push_str(PAD);
                    }
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push_str(PAD);
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    for _ in 0..=indent {
                        out.push_str(PAD);
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push_str(PAD);
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; persist as null like most encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Shortest round-trippable representation Rust offers.
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// A parse error with 1-based line/column context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// What went wrong.
    pub msg: String,
    /// 1-based line of the offending character.
    pub line: usize,
    /// 1-based column of the offending character.
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parses a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        let mut line = 1;
        let mut col = 1;
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { msg: msg.into(), line, col }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("maximum nesting depth exceeded"));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match c {
                            Some(c) => s.push(c),
                            None => return Err(self.err("invalid unicode escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8 in string")),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit in \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("invalid number '{text}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact() {
        let cases = [
            r#"{"a":1,"b":[true,false,null],"c":{"d":"e"}}"#,
            r#"[1,2.5,-3,"x\ny"]"#,
            "{}",
            "[]",
        ];
        for c in cases {
            let v = parse(c).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn roundtrip_pretty() {
        let v = parse(r#"{"a":[1,{"b":2}],"z":"s"}"#).unwrap();
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn escapes() {
        let v = Json::str("line\nquote\"back\\slash\ttab");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair: 😀 U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // raw multibyte utf-8 passes through
        assert_eq!(parse("\"héllo wörld\"").unwrap(), Json::Str("héllo wörld".into()));
    }

    #[test]
    fn canonical_is_key_sorted() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap();
        let b = parse(r#"{"a":2,"z":1}"#).unwrap();
        assert_eq!(a.canonical(), b.canonical());
        assert_eq!(a.canonical(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn errors_have_position() {
        let e = parse("{\n  \"a\": tru\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("true"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn rejects_deep_nesting() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&s).is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(parse("\"abc").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("{\"a\":").is_err());
    }

    #[test]
    fn accessor_helpers() {
        let v = parse(r#"{"n": 7, "f": 1.5, "b": true, "s": "q"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(7));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_i64(), None);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("q"));
        assert!(v.get("missing").is_none());
    }
}
