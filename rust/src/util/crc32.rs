//! CRC-32 (IEEE 802.3 polynomial) for record integrity checks.
//!
//! The segment-log store frames every record with a CRC over its body so
//! a torn write — a crash mid-append, a truncated copy — is *detected*
//! rather than silently decoded into garbage. This is the classic
//! reflected table-driven implementation (polynomial `0xEDB88320`, the
//! same CRC used by gzip and PNG), one table lookup per input byte, built
//! in-tree because the offline image allows no external crates.

/// The reflected CRC-32 polynomial (IEEE 802.3 / gzip / PNG).
const POLY: u32 = 0xEDB8_8320;

/// 256-entry lookup table for the reflected polynomial, built at compile
/// time so the hot path is a single table index per byte.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC-32 of `bytes` in one call.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finish()
}

/// Incremental CRC-32 state, for checksumming data that arrives in
/// chunks (e.g. a record body streamed through a write buffer).
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    /// Fresh state (equivalent to having hashed zero bytes).
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    /// Folds `bytes` into the checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// The final checksum value.
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Hasher {
    fn default() -> Hasher {
        Hasher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for the IEEE polynomial.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let oneshot = crc32(&data);
        for chunk in [1usize, 3, 64, 1000] {
            let mut h = Hasher::new();
            for piece in data.chunks(chunk) {
                h.update(piece);
            }
            assert_eq!(h.finish(), oneshot, "chunk size {chunk}");
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let data = b"segment record body with a payload".to_vec();
        let good = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut bad = data.clone();
                bad[byte] ^= 1 << bit;
                assert_ne!(crc32(&bad), good, "flip at {byte}:{bit} undetected");
            }
        }
    }
}
