//! Lazy field extraction from encoded documents — no tree allocation.
//!
//! Several hot read paths touch one or two fields of a document and
//! throw the rest away: journal replay wants `ts`/`event`/`task` per
//! line, the checkpoint resume probe wants `matrix_fingerprint` and
//! `version` before deciding whether the manifest is even usable, and a
//! cold cache hit wants only `value` out of `{id, params, value}`.
//! Parsing the whole document builds a [`Json`] tree proportional to the
//! *document*, not the *question*. This module answers the question
//! directly: a [`Scanner`] walks the top-level object of a binary
//! ([`crate::util::codec`]) **or** JSON document, skipping unrequested
//! values byte-wise, and yields scalar fields as borrowed [`ScanValue`]s.
//!
//! Composite fields (arrays/objects) come back as raw byte ranges; only
//! an explicit [`ScanValue::materialize`] builds a [`Json`] subtree, and
//! every materialization increments a per-thread counter
//! ([`materialized_count`]) — the test hook that *proves* the
//! scalar-field paths allocate no tree nodes at all.

use crate::util::codec::{self, CodecError};
use crate::util::json::{parse, Json};
use std::borrow::Cow;
use std::cell::Cell;
use std::fmt;

thread_local! {
    /// Per-thread count of [`ScanValue::materialize`] calls. Thread-local
    /// rather than global so a test's before/after delta cannot be
    /// perturbed by scanners running concurrently on other threads.
    static MATERIALIZED: Cell<usize> = const { Cell::new(0) };
}

/// Number of [`Json`] subtree materializations performed by scanners on
/// **this thread** since it started. Monotone; compare before/after
/// deltas around a code path that claims to be allocation-free.
pub fn materialized_count() -> usize {
    MATERIALIZED.with(|c| c.get())
}

/// Scan failure: what went wrong and at which byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScanError {
    /// Human-readable description.
    pub msg: String,
    /// Byte offset in the scanned input.
    pub at: usize,
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "scan error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ScanError {}

impl From<CodecError> for ScanError {
    fn from(e: CodecError) -> ScanError {
        ScanError { msg: e.msg, at: e.at }
    }
}

fn err(msg: impl Into<String>, at: usize) -> ScanError {
    ScanError { msg: msg.into(), at }
}

/// One extracted top-level field. Scalars are decoded in place (strings
/// borrow from the input when no unescaping is needed); composites stay
/// as raw bytes until [`ScanValue::materialize`] is called.
#[derive(Debug, Clone, PartialEq)]
pub enum ScanValue<'a> {
    /// JSON `null`.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number (integers decode to their exact `f64`, as in [`Json`]).
    Num(f64),
    /// A string; borrowed from the input unless JSON escapes forced a copy.
    Str(Cow<'a, str>),
    /// An array or object, still encoded.
    Raw {
        /// The value's encoded bytes (one complete value, no magic byte).
        bytes: &'a [u8],
        /// True when `bytes` is the binary tagged encoding, false for JSON.
        binary: bool,
    },
}

impl<'a> ScanValue<'a> {
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ScanValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as an exact integer (same policy as [`Json::as_i64`]).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ScanValue::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ScanValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ScanValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// True for `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, ScanValue::Null)
    }

    /// Builds the full [`Json`] value. For scalars this is a single node;
    /// for [`ScanValue::Raw`] it parses the deferred subtree. Every call
    /// increments [`materialized_count`] — the allocation-accounting hook.
    pub fn materialize(&self) -> Result<Json, ScanError> {
        MATERIALIZED.with(|c| c.set(c.get() + 1));
        match self {
            ScanValue::Null => Ok(Json::Null),
            ScanValue::Bool(b) => Ok(Json::Bool(*b)),
            ScanValue::Num(n) => Ok(Json::Num(*n)),
            ScanValue::Str(s) => Ok(Json::Str(s.clone().into_owned())),
            ScanValue::Raw { bytes, binary: true } => {
                let mut pos = 0;
                let v = codec::read_value(bytes, &mut pos, 0)?;
                if pos != bytes.len() {
                    return Err(err("trailing bytes after raw value", pos));
                }
                Ok(v)
            }
            ScanValue::Raw { bytes, binary: false } => {
                let text = std::str::from_utf8(bytes)
                    .map_err(|e| err(format!("raw value not utf-8: {e}"), 0))?;
                parse(text).map_err(|e| err(format!("raw value not json: {e}"), 0))
            }
        }
    }
}

/// A lazy reader over one document whose top level is an object.
/// Construction only sniffs the format; each [`Scanner::field`] /
/// [`Scanner::fields`] call is a single skip-walk over the top-level
/// entries.
pub struct Scanner<'a> {
    bytes: &'a [u8],
    binary: bool,
    /// Byte offset of the top-level object's tag/brace. A full binary
    /// document starts at 2 (magic + `TAG_OBJ`); a nested raw binary
    /// value has no magic byte and starts at 1 (`TAG_OBJ`); JSON always
    /// re-scans from 0 (leading whitespace is skipped in the walk).
    start: usize,
}

impl<'a> Scanner<'a> {
    /// Wraps `bytes`, auto-detecting binary (leading
    /// [`codec::BINARY_MAGIC`]) vs JSON text. The document must be a
    /// top-level object in either format.
    pub fn new(bytes: &'a [u8]) -> Result<Scanner<'a>, ScanError> {
        let binary = codec::is_binary(bytes);
        if binary {
            if bytes.get(1) != Some(&codec::TAG_OBJ) {
                return Err(err("binary document is not an object", 1));
            }
        } else {
            let start = bytes
                .iter()
                .position(|b| !b" \t\r\n".contains(b))
                .ok_or_else(|| err("empty document", 0))?;
            if bytes[start] != b'{' {
                return Err(err("json document is not an object", start));
            }
        }
        Ok(Scanner { bytes, binary, start: if binary { 2 } else { 0 } })
    }

    /// Wraps an already-captured composite value ([`ScanValue::Raw`]) so
    /// its *own* fields can be probed lazily, without materializing it.
    /// The raw value must be an object. This is how nested subtrees —
    /// e.g. the `params` object inside a store record — get the same
    /// zero-allocation field access as a top-level document: raw binary
    /// bytes are one complete tagged value (no magic byte), so the walk
    /// starts at the `TAG_OBJ` tag instead of past a header.
    pub fn from_raw(raw: &ScanValue<'a>) -> Result<Scanner<'a>, ScanError> {
        match raw {
            ScanValue::Raw { bytes, binary: true } => {
                if bytes.first() != Some(&codec::TAG_OBJ) {
                    return Err(err("raw binary value is not an object", 0));
                }
                Ok(Scanner { bytes, binary: true, start: 1 })
            }
            ScanValue::Raw { bytes, binary: false } => {
                let at = bytes
                    .iter()
                    .position(|b| !b" \t\r\n".contains(b))
                    .ok_or_else(|| err("empty raw value", 0))?;
                if bytes[at] != b'{' {
                    return Err(err("raw json value is not an object", at));
                }
                Ok(Scanner { bytes, binary: false, start: 0 })
            }
            _ => Err(err("scalar value has no fields", 0)),
        }
    }

    /// Extracts one named top-level field; `Ok(None)` when absent.
    pub fn field(&self, name: &str) -> Result<Option<ScanValue<'a>>, ScanError> {
        let mut out = [None];
        self.scan(&[name], &mut out)?;
        Ok(out[0].take())
    }

    /// Extracts up to `N` named top-level fields in **one pass**; each
    /// slot is `None` when the corresponding field is absent. Duplicate
    /// keys keep the first occurrence.
    pub fn fields<const N: usize>(
        &self,
        names: [&str; N],
    ) -> Result<[Option<ScanValue<'a>>; N], ScanError> {
        let mut out: [Option<ScanValue<'a>>; N] = std::array::from_fn(|_| None);
        self.scan(&names, &mut out)?;
        Ok(out)
    }

    fn scan(
        &self,
        names: &[&str],
        out: &mut [Option<ScanValue<'a>>],
    ) -> Result<(), ScanError> {
        if self.binary {
            self.scan_binary(names, out)
        } else {
            self.scan_json(names, out)
        }
    }

    // ---- binary walk ----------------------------------------------------

    fn scan_binary(
        &self,
        names: &[&str],
        out: &mut [Option<ScanValue<'a>>],
    ) -> Result<(), ScanError> {
        let bytes = self.bytes;
        let mut pos = self.start; // at TAG_OBJ+1, verified at construction
        let count = codec::read_varint(bytes, &mut pos)?;
        let mut remaining = names.len();
        for _ in 0..count {
            let key_len = codec::read_varint(bytes, &mut pos)? as usize;
            let key_end = pos
                .checked_add(key_len)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| err("truncated object key", pos))?;
            let key = &bytes[pos..key_end];
            pos = key_end;
            let slot = names
                .iter()
                .position(|n| n.as_bytes() == key)
                .filter(|&i| out[i].is_none());
            match slot {
                Some(i) if remaining > 0 => {
                    out[i] = Some(Self::capture_binary(bytes, &mut pos)?);
                    remaining -= 1;
                    if remaining == 0 {
                        return Ok(());
                    }
                }
                _ => codec::skip_value(bytes, &mut pos)?,
            }
        }
        Ok(())
    }

    fn capture_binary(bytes: &'a [u8], pos: &mut usize) -> Result<ScanValue<'a>, ScanError> {
        let tag = *bytes.get(*pos).ok_or_else(|| err("truncated value tag", *pos))?;
        match tag {
            codec::TAG_NULL => {
                *pos += 1;
                Ok(ScanValue::Null)
            }
            codec::TAG_FALSE => {
                *pos += 1;
                Ok(ScanValue::Bool(false))
            }
            codec::TAG_TRUE => {
                *pos += 1;
                Ok(ScanValue::Bool(true))
            }
            codec::TAG_INT => {
                *pos += 1;
                let raw = codec::read_varint(bytes, pos)?;
                Ok(ScanValue::Num(codec::unzigzag(raw) as f64))
            }
            codec::TAG_F64 => {
                *pos += 1;
                let end = pos
                    .checked_add(8)
                    .filter(|&e| e <= bytes.len())
                    .ok_or_else(|| err("truncated f64", *pos))?;
                let mut raw = [0u8; 8];
                raw.copy_from_slice(&bytes[*pos..end]);
                *pos = end;
                Ok(ScanValue::Num(f64::from_le_bytes(raw)))
            }
            codec::TAG_STR => {
                *pos += 1;
                let len = codec::read_varint(bytes, pos)? as usize;
                let end = pos
                    .checked_add(len)
                    .filter(|&e| e <= bytes.len())
                    .ok_or_else(|| err("truncated string", *pos))?;
                let s = std::str::from_utf8(&bytes[*pos..end])
                    .map_err(|e| err(format!("string not utf-8: {e}"), *pos))?;
                *pos = end;
                Ok(ScanValue::Str(Cow::Borrowed(s)))
            }
            codec::TAG_ARR | codec::TAG_OBJ => {
                let start = *pos;
                codec::skip_value(bytes, pos)?;
                Ok(ScanValue::Raw { bytes: &bytes[start..*pos], binary: true })
            }
            other => Err(err(format!("unknown value tag 0x{other:02x}"), *pos)),
        }
    }

    // ---- json walk ------------------------------------------------------

    fn scan_json(
        &self,
        names: &[&str],
        out: &mut [Option<ScanValue<'a>>],
    ) -> Result<(), ScanError> {
        let b = self.bytes;
        let mut pos = 0;
        skip_ws(b, &mut pos);
        expect(b, &mut pos, b'{')?;
        skip_ws(b, &mut pos);
        if peek(b, pos) == Some(b'}') {
            return Ok(());
        }
        let mut remaining = names.len();
        loop {
            skip_ws(b, &mut pos);
            let key = json_string(b, &mut pos)?;
            skip_ws(b, &mut pos);
            expect(b, &mut pos, b':')?;
            skip_ws(b, &mut pos);
            let slot = names
                .iter()
                .position(|n| key_matches(&key, n))
                .filter(|&i| out[i].is_none());
            match slot {
                Some(i) if remaining > 0 => {
                    out[i] = Some(capture_json(b, &mut pos)?);
                    remaining -= 1;
                }
                _ => skip_json_value(b, &mut pos, 0)?,
            }
            skip_ws(b, &mut pos);
            match bump(b, &mut pos) {
                Some(b',') => {
                    if remaining == 0 {
                        return Ok(());
                    }
                }
                Some(b'}') => return Ok(()),
                _ => return Err(err("expected ',' or '}' in object", pos)),
            }
        }
    }
}

/// A scanned JSON object key: raw bytes plus whether any escape was seen
/// (escaped keys are compared after unescaping — the rare path).
struct JsonKey<'a> {
    raw: &'a [u8],
    escaped: bool,
}

fn key_matches(key: &JsonKey<'_>, name: &str) -> bool {
    if !key.escaped {
        return key.raw == name.as_bytes();
    }
    match unescape(key.raw) {
        Ok(s) => s == name,
        Err(_) => false,
    }
}

fn peek(b: &[u8], pos: usize) -> Option<u8> {
    b.get(pos).copied()
}

fn bump(b: &[u8], pos: &mut usize) -> Option<u8> {
    let v = peek(b, *pos);
    if v.is_some() {
        *pos += 1;
    }
    v
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while matches!(peek(b, *pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, want: u8) -> Result<(), ScanError> {
    if peek(b, *pos) == Some(want) {
        *pos += 1;
        Ok(())
    } else {
        Err(err(format!("expected '{}'", want as char), *pos))
    }
}

/// Scans a JSON string token (starting at `"`), returning its raw
/// contents without unescaping. Escapes are validated just enough to find
/// the closing quote safely.
fn json_string<'a>(b: &'a [u8], pos: &mut usize) -> Result<JsonKey<'a>, ScanError> {
    expect(b, pos, b'"')?;
    let start = *pos;
    let mut escaped = false;
    loop {
        match bump(b, pos) {
            None => return Err(err("unterminated string", *pos)),
            Some(b'"') => {
                return Ok(JsonKey { raw: &b[start..*pos - 1], escaped });
            }
            Some(b'\\') => {
                escaped = true;
                if bump(b, pos).is_none() {
                    return Err(err("unterminated escape", *pos));
                }
            }
            Some(_) => {}
        }
    }
}

/// Unescapes a raw JSON string body (the bytes between the quotes).
fn unescape(raw: &[u8]) -> Result<String, ScanError> {
    let mut s = String::with_capacity(raw.len());
    let mut pos = 0;
    while let Some(c) = bump(raw, &mut pos) {
        if c != b'\\' {
            // Copy the longest escape-free run in one shot (multi-byte
            // UTF-8 passes through untouched).
            let start = pos - 1;
            while matches!(peek(raw, pos), Some(c) if c != b'\\') {
                pos += 1;
            }
            let chunk = std::str::from_utf8(&raw[start..pos])
                .map_err(|e| err(format!("string not utf-8: {e}"), start))?;
            s.push_str(chunk);
            continue;
        }
        match bump(raw, &mut pos) {
            Some(b'"') => s.push('"'),
            Some(b'\\') => s.push('\\'),
            Some(b'/') => s.push('/'),
            Some(b'b') => s.push('\u{8}'),
            Some(b'f') => s.push('\u{c}'),
            Some(b'n') => s.push('\n'),
            Some(b'r') => s.push('\r'),
            Some(b't') => s.push('\t'),
            Some(b'u') => {
                let cp = hex4(raw, &mut pos)?;
                let c = if (0xD800..0xDC00).contains(&cp) {
                    if bump(raw, &mut pos) != Some(b'\\') || bump(raw, &mut pos) != Some(b'u') {
                        return Err(err("expected low surrogate", pos));
                    }
                    let lo = hex4(raw, &mut pos)?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(err("invalid low surrogate", pos));
                    }
                    char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                } else {
                    char::from_u32(cp)
                };
                match c {
                    Some(c) => s.push(c),
                    None => return Err(err("invalid unicode escape", pos)),
                }
            }
            _ => return Err(err("invalid escape sequence", pos)),
        }
    }
    Ok(s)
}

fn hex4(b: &[u8], pos: &mut usize) -> Result<u32, ScanError> {
    let mut v = 0u32;
    for _ in 0..4 {
        let c = bump(b, pos).ok_or_else(|| err("truncated \\u escape", *pos))?;
        let d = (c as char)
            .to_digit(16)
            .ok_or_else(|| err("invalid hex digit in \\u escape", *pos))?;
        v = v * 16 + d;
    }
    Ok(v)
}

/// Captures one JSON value as a [`ScanValue`], decoding scalars in place.
fn capture_json<'a>(b: &'a [u8], pos: &mut usize) -> Result<ScanValue<'a>, ScanError> {
    match peek(b, *pos) {
        Some(b'n') => {
            literal(b, pos, b"null")?;
            Ok(ScanValue::Null)
        }
        Some(b't') => {
            literal(b, pos, b"true")?;
            Ok(ScanValue::Bool(true))
        }
        Some(b'f') => {
            literal(b, pos, b"false")?;
            Ok(ScanValue::Bool(false))
        }
        Some(b'"') => {
            let key = json_string(b, pos)?;
            if key.escaped {
                Ok(ScanValue::Str(Cow::Owned(unescape(key.raw)?)))
            } else {
                let s = std::str::from_utf8(key.raw)
                    .map_err(|e| err(format!("string not utf-8: {e}"), *pos))?;
                Ok(ScanValue::Str(Cow::Borrowed(s)))
            }
        }
        Some(c) if c == b'-' || c.is_ascii_digit() => {
            let start = *pos;
            skip_json_number(b, pos);
            let text = std::str::from_utf8(&b[start..*pos])
                .map_err(|e| err(format!("number not utf-8: {e}"), start))?;
            text.parse::<f64>()
                .map(ScanValue::Num)
                .map_err(|_| err(format!("invalid number '{text}'"), start))
        }
        Some(b'{') | Some(b'[') => {
            let start = *pos;
            skip_json_value(b, pos, 0)?;
            Ok(ScanValue::Raw { bytes: &b[start..*pos], binary: false })
        }
        Some(c) => Err(err(format!("unexpected character '{}'", c as char), *pos)),
        None => Err(err("unexpected end of input", *pos)),
    }
}

fn literal(b: &[u8], pos: &mut usize, word: &[u8]) -> Result<(), ScanError> {
    if b[*pos..].starts_with(word) {
        *pos += word.len();
        Ok(())
    } else {
        Err(err("invalid literal", *pos))
    }
}

fn skip_json_number(b: &[u8], pos: &mut usize) {
    if peek(b, *pos) == Some(b'-') {
        *pos += 1;
    }
    while matches!(peek(b, *pos), Some(c) if c.is_ascii_digit()) {
        *pos += 1;
    }
    if peek(b, *pos) == Some(b'.') {
        *pos += 1;
        while matches!(peek(b, *pos), Some(c) if c.is_ascii_digit()) {
            *pos += 1;
        }
    }
    if matches!(peek(b, *pos), Some(b'e' | b'E')) {
        *pos += 1;
        if matches!(peek(b, *pos), Some(b'+' | b'-')) {
            *pos += 1;
        }
        while matches!(peek(b, *pos), Some(c) if c.is_ascii_digit()) {
            *pos += 1;
        }
    }
}

/// Advances past one JSON value without building anything. Depth-bounded
/// like the tree parser.
fn skip_json_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<(), ScanError> {
    const MAX_DEPTH: usize = 128;
    if depth >= MAX_DEPTH {
        return Err(err("maximum nesting depth exceeded", *pos));
    }
    skip_ws(b, pos);
    match peek(b, *pos) {
        Some(b'n') => literal(b, pos, b"null"),
        Some(b't') => literal(b, pos, b"true"),
        Some(b'f') => literal(b, pos, b"false"),
        Some(b'"') => json_string(b, pos).map(|_| ()),
        Some(c) if c == b'-' || c.is_ascii_digit() => {
            skip_json_number(b, pos);
            Ok(())
        }
        Some(b'[') => {
            *pos += 1;
            skip_ws(b, pos);
            if peek(b, *pos) == Some(b']') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_json_value(b, pos, depth + 1)?;
                skip_ws(b, pos);
                match bump(b, pos) {
                    Some(b',') => continue,
                    Some(b']') => return Ok(()),
                    _ => return Err(err("expected ',' or ']' in array", *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            skip_ws(b, pos);
            if peek(b, *pos) == Some(b'}') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                json_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                skip_json_value(b, pos, depth + 1)?;
                skip_ws(b, pos);
                match bump(b, pos) {
                    Some(b',') => continue,
                    Some(b'}') => return Ok(()),
                    _ => return Err(err("expected ',' or '}' in object", *pos)),
                }
            }
        }
        Some(c) => Err(err(format!("unexpected character '{}'", c as char), *pos)),
        None => Err(err("unexpected end of input", *pos)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::encode;
    use crate::util::json::Json;

    fn sample() -> Json {
        Json::obj(vec![
            ("attempt", Json::int(3)),
            ("duration_secs", Json::Num(0.125)),
            ("event", Json::str("succeeded")),
            ("nested", Json::obj(vec![("deep", Json::arr(vec![Json::int(1), Json::str("x")]))])),
            ("ok", Json::Bool(true)),
            ("task", Json::str("abc123")),
            ("ts", Json::Num(1_700_000_000.5)),
            ("zero", Json::Null),
        ])
    }

    fn both_encodings(doc: &Json) -> [Vec<u8>; 2] {
        [encode(doc), doc.to_string().into_bytes()]
    }

    #[test]
    fn scalar_fields_extract_identically_from_both_formats() {
        for bytes in both_encodings(&sample()) {
            let s = Scanner::new(&bytes).unwrap();
            assert_eq!(s.field("event").unwrap().unwrap().as_str(), Some("succeeded"));
            assert_eq!(s.field("attempt").unwrap().unwrap().as_i64(), Some(3));
            assert_eq!(s.field("duration_secs").unwrap().unwrap().as_f64(), Some(0.125));
            assert_eq!(s.field("ok").unwrap().unwrap().as_bool(), Some(true));
            assert!(s.field("zero").unwrap().unwrap().is_null());
            assert_eq!(s.field("ts").unwrap().unwrap().as_f64(), Some(1_700_000_000.5));
            assert!(s.field("missing").unwrap().is_none());
        }
    }

    #[test]
    fn multi_field_single_pass() {
        for bytes in both_encodings(&sample()) {
            let s = Scanner::new(&bytes).unwrap();
            let [ev, task, attempt, nope] =
                s.fields(["event", "task", "attempt", "nope"]).unwrap();
            assert_eq!(ev.unwrap().as_str(), Some("succeeded"));
            assert_eq!(task.unwrap().as_str(), Some("abc123"));
            assert_eq!(attempt.unwrap().as_i64(), Some(3));
            assert!(nope.is_none());
        }
    }

    #[test]
    fn composite_fields_materialize_correctly() {
        let doc = sample();
        for bytes in both_encodings(&doc) {
            let s = Scanner::new(&bytes).unwrap();
            let nested = s.field("nested").unwrap().unwrap();
            assert!(matches!(nested, ScanValue::Raw { .. }));
            assert_eq!(&nested.materialize().unwrap(), doc.get("nested").unwrap());
        }
    }

    #[test]
    fn single_scalar_field_path_allocates_zero_tree_nodes() {
        // The tentpole claim: probing one scalar field must not build any
        // Json nodes, however large the rest of the document is.
        let mut big = vec![("needle", Json::str("found"))];
        let filler: Vec<(String, Json)> = (0..200)
            .map(|i| {
                (
                    format!("filler{i:03}"),
                    Json::obj(vec![("xs", Json::arr((0..20).map(Json::int).collect()))]),
                )
            })
            .collect();
        for (k, v) in &filler {
            big.push((k.as_str(), v.clone()));
        }
        let doc = Json::obj(big);
        for bytes in both_encodings(&doc) {
            let before = materialized_count();
            let s = Scanner::new(&bytes).unwrap();
            let v = s.field("needle").unwrap().unwrap();
            assert_eq!(v.as_str(), Some("found"));
            assert_eq!(
                materialized_count(),
                before,
                "scalar probe must not materialize any tree"
            );
            // Borrowed straight from the input on both formats.
            assert!(matches!(v, ScanValue::Str(Cow::Borrowed(_))));
        }
    }

    #[test]
    fn nested_raw_objects_scan_without_materializing() {
        let doc = Json::obj(vec![
            ("id", Json::str("t1")),
            (
                "params",
                Json::obj(vec![
                    ("lr", Json::Num(0.05)),
                    ("model", Json::str("svc")),
                    ("folds", Json::int(5)),
                ]),
            ),
            ("value", Json::arr(vec![Json::int(1), Json::int(2)])),
        ]);
        for bytes in both_encodings(&doc) {
            let before = materialized_count();
            let outer = Scanner::new(&bytes).unwrap();
            let params = outer.field("params").unwrap().unwrap();
            let inner = Scanner::from_raw(&params).unwrap();
            assert_eq!(inner.field("model").unwrap().unwrap().as_str(), Some("svc"));
            assert_eq!(inner.field("lr").unwrap().unwrap().as_f64(), Some(0.05));
            assert_eq!(inner.field("folds").unwrap().unwrap().as_i64(), Some(5));
            assert!(inner.field("absent").unwrap().is_none());
            assert_eq!(
                materialized_count(),
                before,
                "nested scalar probes must not materialize any tree"
            );
            // Scalars and arrays have no fields to scan.
            let id = outer.field("id").unwrap().unwrap();
            assert!(Scanner::from_raw(&id).is_err());
            let arr = outer.field("value").unwrap().unwrap();
            assert!(Scanner::from_raw(&arr).is_err());
        }
    }

    #[test]
    fn json_escapes_and_whitespace_are_handled() {
        let text = " {\n  \"a\\nb\" : \"line\\u0031\\n\\\"q\\\"\",\n  \"plain\": 2e3 ,\n  \"s\": \"😀é\"\n} ";
        let s = Scanner::new(text.as_bytes()).unwrap();
        assert_eq!(s.field("a\nb").unwrap().unwrap().as_str(), Some("line1\n\"q\""));
        assert_eq!(s.field("plain").unwrap().unwrap().as_f64(), Some(2000.0));
        assert_eq!(s.field("s").unwrap().unwrap().as_str(), Some("😀é"));
    }

    #[test]
    fn surrogate_pair_escapes_unescape() {
        let text = r#"{"emoji": "😀"}"#;
        let s = Scanner::new(text.as_bytes()).unwrap();
        assert_eq!(s.field("emoji").unwrap().unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn non_object_documents_are_rejected() {
        assert!(Scanner::new(b"[1,2]").is_err());
        assert!(Scanner::new(b"42").is_err());
        assert!(Scanner::new(b"").is_err());
        assert!(Scanner::new(&encode(&Json::arr(vec![Json::int(1)]))).is_err());
    }

    #[test]
    fn corrupt_documents_error_not_panic() {
        // Truncated binary object mid-entry.
        let full = encode(&sample());
        for cut in 3..full.len() {
            let s = Scanner::new(&full[..cut]).unwrap();
            // Either the field is cleanly absent (cut before it) or the
            // walk errors; it must never panic or fabricate a value.
            let _ = s.field("zero");
        }
        // Malformed JSON bodies.
        for bad in ["{\"a\": }", "{\"a\" 1}", "{\"a\": tru}", "{\"a\": \"x"] {
            let s = Scanner::new(bad.as_bytes()).unwrap();
            assert!(s.field("a").is_err(), "{bad} must error");
        }
    }

    #[test]
    fn duplicate_keys_keep_first() {
        let s = Scanner::new(br#"{"k": 1, "k": 2}"#).unwrap();
        assert_eq!(s.field("k").unwrap().unwrap().as_i64(), Some(1));
    }

    #[test]
    fn early_exit_after_last_requested_field() {
        // Garbage after the requested fields is never reached: the walk
        // stops as soon as every slot fills.
        let text = br#"{"a": 1, "b": 2, "broken": <<<}"#;
        let s = Scanner::new(text).unwrap();
        let [a, b] = s.fields(["a", "b"]).unwrap();
        assert_eq!(a.unwrap().as_i64(), Some(1));
        assert_eq!(b.unwrap().as_i64(), Some(2));
    }
}
