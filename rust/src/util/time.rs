//! Timing helpers: a monotonic stopwatch and human-readable durations.

use std::time::{Duration, Instant};

/// A simple monotonic stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Starts timing from now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Time elapsed since `start`/`restart`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed time in (fractional) seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed time in (fractional) milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Returns the elapsed time and resets the start point to now.
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Formats a duration compactly: `812ns`, `3.4µs`, `12.3ms`, `1.24s`, `2m03s`.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else if ns < 60 * 1_000_000_000u128 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else {
        let secs = d.as_secs();
        format!("{}m{:02}s", secs / 60, secs % 60)
    }
}

/// Formats seconds (f64) with the same rules.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() || s < 0.0 {
        return "?".to_string();
    }
    fmt_duration(Duration::from_secs_f64(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
        let mut sw2 = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let lap = sw2.restart();
        assert!(lap.as_millis() >= 1);
        assert!(sw2.elapsed() < lap + Duration::from_millis(50));
    }

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_duration(Duration::from_nanos(812)), "812ns");
        assert_eq!(fmt_duration(Duration::from_micros(3)), "3.0µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.0ms");
        assert_eq!(fmt_duration(Duration::from_secs(1)), "1.00s");
        assert_eq!(fmt_duration(Duration::from_secs(123)), "2m03s");
        assert_eq!(fmt_secs(f64::NAN), "?");
    }
}
