//! A fixed-size **work-stealing** worker thread pool.
//!
//! `tokio`/`rayon` are unavailable offline, and Memento's execution model —
//! N OS threads pulling self-contained experiment tasks — is exactly what
//! the paper describes ("concurrently run experiments across multiple
//! threads"), so a small dedicated pool is both sufficient and faithful.
//!
//! # Design
//!
//! - one [`WorkQueue`] per worker; submissions round-robin across the
//!   worker queues so no single mutex serializes the hot path (the
//!   previous design's single `Mutex<VecDeque>` queue was the bottleneck
//!   at short task lengths — see `benches/scheduler.rs`);
//! - a worker takes jobs in priority order: **own queue (FIFO) → steal
//!   from a sibling (back end)**; see [`crate::util::deque`] for the
//!   FIFO-fairness rationale;
//! - jobs are `FnOnce` boxes; panics inside a job are caught per-job so a
//!   single failing experiment cannot take a worker down (the paper's
//!   per-task error isolation);
//! - [`ThreadPool::join`] blocks until every submitted job finished;
//! - [`ThreadPool::execute_batch`] submits many jobs with one lock
//!   acquisition per worker queue — the scheduler's batched dispatch path;
//! - [`ThreadPool::stats`] exposes steal/pop counters so schedulers can
//!   report load-balance behaviour ([`crate::coordinator::metrics`]).
//!
//! Sleeping workers park on a condvar with a short timeout; producers
//! increment a `pending` count *before* pushing and notify under the sleep
//! mutex, which rules out lost-wakeup hangs (the timeout is a second line
//! of defence, not the correctness mechanism).

use crate::util::deque::WorkQueue;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Snapshot of the pool's load-balance counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Jobs a worker took from its own queue.
    pub local_pops: usize,
    /// Jobs taken from a *sibling's* queue (the steal path).
    pub steals: usize,
}

struct Shared {
    /// Per-worker queues; owner pops the front, thieves the back.
    locals: Vec<WorkQueue<Job>>,
    /// Jobs pushed but not yet popped, across all queues. Incremented
    /// *before* the push so a worker that observes 0 while holding
    /// `sleep_mx` can safely wait.
    pending: AtomicUsize,
    sleep_mx: Mutex<()>,
    wake_cv: Condvar,
    /// Jobs submitted but not yet finished (queued + running).
    inflight: AtomicUsize,
    idle_mx: Mutex<()>,
    idle_cv: Condvar,
    shutdown: AtomicBool,
    /// Count of jobs that panicked (the panic itself is contained).
    panics: AtomicUsize,
    local_pops: AtomicUsize,
    steals: AtomicUsize,
}

/// A fixed-size work-stealing thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    /// Round-robin cursor for [`ThreadPool::execute`].
    next: AtomicUsize,
}

impl ThreadPool {
    /// Spawns `size` worker threads (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            locals: (0..size).map(|_| WorkQueue::new()).collect(),
            pending: AtomicUsize::new(0),
            sleep_mx: Mutex::new(()),
            wake_cv: Condvar::new(),
            inflight: AtomicUsize::new(0),
            idle_mx: Mutex::new(()),
            idle_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            panics: AtomicUsize::new(0),
            local_pops: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("memento-worker-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, size, next: AtomicUsize::new(0) }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submits a job to the next worker queue (round-robin). Panics in the
    /// job are contained and counted, not propagated (callers that need the
    /// outcome should collect it themselves).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let idx = self.next.fetch_add(1, Ordering::Relaxed) % self.size;
        self.submit_to(idx, Box::new(f));
    }

    /// Submits a job to a *specific* worker's queue. The job still runs
    /// exactly once but may be stolen by a sibling if worker `idx` is busy —
    /// this is a locality hint, not an affinity guarantee.
    pub fn execute_pinned<F: FnOnce() + Send + 'static>(&self, idx: usize, f: F) {
        self.submit_to(idx % self.size, Box::new(f));
    }

    fn submit_to(&self, idx: usize, job: Job) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        // pending must rise before the push (see Shared::pending).
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.shared.locals[idx].push(job);
        self.wake(false);
    }

    /// Submits a batch of jobs, striping them round-robin across the worker
    /// queues with one lock acquisition per queue. This is the scheduler's
    /// dispatch path: for `k` jobs it costs `min(k, size)` locks instead of
    /// `k`, and wakes all workers once.
    pub fn execute_batch<F: FnOnce() + Send + 'static>(&self, jobs: Vec<F>) {
        let k = jobs.len();
        if k == 0 {
            return;
        }
        self.shared.inflight.fetch_add(k, Ordering::SeqCst);
        self.shared.pending.fetch_add(k, Ordering::SeqCst);
        let start = self.next.fetch_add(k, Ordering::Relaxed);
        let mut striped: Vec<Vec<Job>> = (0..self.size).map(|_| Vec::new()).collect();
        for (i, f) in jobs.into_iter().enumerate() {
            striped[(start + i) % self.size].push(Box::new(f));
        }
        for (idx, stripe) in striped.into_iter().enumerate() {
            if !stripe.is_empty() {
                self.shared.locals[idx].push_batch(stripe);
            }
        }
        self.wake(true);
    }

    fn wake(&self, all: bool) {
        // Taking (and releasing) sleep_mx orders this wake-up after any
        // in-progress "check pending, then wait" on the worker side.
        drop(self.shared.sleep_mx.lock().unwrap());
        if all {
            self.shared.wake_cv.notify_all();
        } else {
            self.shared.wake_cv.notify_one();
        }
    }

    /// Blocks until every submitted job has finished.
    pub fn join(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }

    /// Number of jobs currently queued or running.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Number of jobs that ended in a contained panic so far.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Load-balance counters accumulated since construction.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            local_pops: self.shared.local_pops.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        drop(self.shared.sleep_mx.lock().unwrap());
        self.shared.wake_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Take the next job for worker `me`: own queue first, then steal.
fn find_job(sh: &Shared, me: usize) -> Option<Job> {
    if let Some(job) = sh.locals[me].pop() {
        sh.pending.fetch_sub(1, Ordering::SeqCst);
        sh.local_pops.fetch_add(1, Ordering::Relaxed);
        return Some(job);
    }
    let n = sh.locals.len();
    for k in 1..n {
        let victim = (me + k) % n;
        if let Some(job) = sh.locals[victim].steal() {
            sh.pending.fetch_sub(1, Ordering::SeqCst);
            sh.steals.fetch_add(1, Ordering::Relaxed);
            return Some(job);
        }
    }
    None
}

fn worker_loop(sh: Arc<Shared>, me: usize) {
    loop {
        let job = match find_job(&sh, me) {
            Some(job) => job,
            None => {
                // Queues drained: exit on shutdown, otherwise park. The
                // pending re-check under sleep_mx pairs with the producer's
                // increment-then-lock ordering; the timeout only bounds the
                // cost of pathological races, it is not load-bearing.
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let guard = sh.sleep_mx.lock().unwrap();
                if sh.pending.load(Ordering::SeqCst) == 0
                    && !sh.shutdown.load(Ordering::SeqCst)
                {
                    let _ = sh
                        .wake_cv
                        .wait_timeout(guard, Duration::from_millis(10))
                        .unwrap();
                }
                continue;
            }
        };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            sh.panics.fetch_add(1, Ordering::SeqCst);
        }
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.idle_mx.lock().unwrap();
            sh.idle_cv.notify_all();
        }
    }
}

/// Runs `items.len()` closures on a temporary pool of `workers` threads and
/// returns their results in input order. Panicking closures yield `None`.
pub fn scope_run<T, I, F>(workers: usize, items: Vec<I>, f: F) -> Vec<Option<T>>
where
    T: Send + 'static,
    I: Send + 'static,
    F: Fn(I) -> T + Send + Sync + 'static,
{
    let pool = ThreadPool::new(workers.max(1));
    let n = items.len();
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let f = Arc::new(f);
    let jobs: Vec<_> = items
        .into_iter()
        .enumerate()
        .map(|(i, item)| {
            let results = Arc::clone(&results);
            let f = Arc::clone(&f);
            move || {
                let out = f(item);
                results.lock().unwrap()[i] = Some(out);
            }
        })
        .collect();
    pool.execute_batch(jobs);
    pool.join();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("pool joined but results still shared"))
        .into_inner()
        .unwrap()
}

/// Returns the number of logical CPUs (parsed from /proc; fallback 4).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn join_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // must not hang
    }

    #[test]
    fn panics_are_contained() {
        let pool = ThreadPool::new(2);
        let ok = Arc::new(AtomicUsize::new(0));
        for i in 0..10 {
            let ok = Arc::clone(&ok);
            pool.execute(move || {
                if i % 2 == 0 {
                    panic!("boom {i}");
                }
                ok.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(ok.load(Ordering::SeqCst), 5);
        assert_eq!(pool.panic_count(), 5);
    }

    #[test]
    fn actually_parallel() {
        // With 4 workers, 4 jobs that each wait for the others to start
        // must all be running at once or this deadlocks (bounded by timeout).
        let pool = ThreadPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        for _ in 0..4 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                b.wait();
            });
        }
        pool.join();
    }

    #[test]
    fn idle_workers_steal_pinned_backlog() {
        // Two jobs pinned to worker 0; the first blocks until the second
        // runs. Worker 0 is stuck inside job A, so job B can only run if a
        // sibling steals it — completion proves the steal path works, and
        // the counter must record it.
        let pool = ThreadPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let b1 = Arc::clone(&barrier);
        let b2 = Arc::clone(&barrier);
        pool.execute_pinned(0, move || {
            b1.wait();
        });
        pool.execute_pinned(0, move || {
            b2.wait();
        });
        pool.join();
        assert!(pool.stats().steals >= 1, "stats: {:?}", pool.stats());
    }

    #[test]
    fn execute_batch_runs_everything() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..500)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.execute_batch(jobs);
        pool.execute_batch(Vec::<fn()>::new()); // empty batch is a no-op
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 500);
        let s = pool.stats();
        assert_eq!(s.local_pops + s.steals, 500);
    }

    #[test]
    fn scope_run_preserves_order() {
        let out = scope_run(3, (0..50).collect::<Vec<u64>>(), |i| i * 2);
        let got: Vec<u64> = out.into_iter().map(|o| o.unwrap()).collect();
        assert_eq!(got, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_run_panics_become_none() {
        let out = scope_run(2, vec![1u64, 2, 3], |i| {
            if i == 2 {
                panic!("no");
            }
            i
        });
        assert_eq!(out[0], Some(1));
        assert_eq!(out[1], None);
        assert_eq!(out[2], Some(3));
    }

    #[test]
    fn reuse_after_join() {
        let pool = ThreadPool::new(2);
        let sum = Arc::new(AtomicU64::new(0));
        for round in 0..3u64 {
            for i in 0..10u64 {
                let s = Arc::clone(&sum);
                pool.execute(move || {
                    s.fetch_add(round * 10 + i, Ordering::SeqCst);
                });
            }
            pool.join();
        }
        let expected: u64 = (0..30u64).sum();
        assert_eq!(sum.load(Ordering::SeqCst), expected);
    }

    #[test]
    fn single_worker_pool_runs_batch_in_order() {
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let jobs: Vec<_> = (0..20)
            .map(|i| {
                let o = Arc::clone(&order);
                move || o.lock().unwrap().push(i)
            })
            .collect();
        pool.execute_batch(jobs);
        pool.join();
        assert_eq!(*order.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
