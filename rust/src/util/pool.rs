//! A fixed-size worker thread pool.
//!
//! `tokio`/`rayon` are unavailable offline, and Memento's execution model —
//! N OS threads pulling self-contained experiment tasks off a FIFO queue —
//! is exactly what the paper describes ("concurrently run experiments across
//! multiple threads"), so a small dedicated pool is both sufficient and
//! faithful.
//!
//! Design:
//! - a `Mutex<VecDeque<Job>>` + `Condvar` injector queue,
//! - jobs are `FnOnce` boxes; panics inside a job are caught per-job so a
//!   single failing experiment cannot take a worker down (the paper's
//!   per-task error isolation),
//! - [`ThreadPool::join`] drains the queue and blocks until idle,
//! - [`scope_run`] convenience for fork/join batches.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    /// Jobs submitted but not yet finished (queued + running).
    inflight: AtomicUsize,
    idle_cv: Condvar,
    idle_mx: Mutex<()>,
    shutdown: AtomicBool,
    /// Count of jobs that panicked (the panic itself is contained).
    panics: AtomicUsize,
}

/// A fixed-size thread pool executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawns `size` worker threads (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            inflight: AtomicUsize::new(0),
            idle_cv: Condvar::new(),
            idle_mx: Mutex::new(()),
            shutdown: AtomicBool::new(false),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("memento-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submits a job. Panics in the job are contained and counted, not
    /// propagated (callers that need the outcome should channel it out).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(f));
        }
        self.shared.cv.notify_one();
    }

    /// Blocks until every submitted job has finished.
    pub fn join(&self) {
        let mut guard = self.shared.idle_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cv.wait(guard).unwrap();
        }
    }

    /// Number of jobs currently queued or running.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Number of jobs that ended in a contained panic so far.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            sh.panics.fetch_add(1, Ordering::SeqCst);
        }
        if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = sh.idle_mx.lock().unwrap();
            sh.idle_cv.notify_all();
        }
    }
}

/// Runs `items.len()` closures on a temporary pool of `workers` threads and
/// returns their results in input order. Panicking closures yield `None`.
pub fn scope_run<T, I, F>(workers: usize, items: Vec<I>, f: F) -> Vec<Option<T>>
where
    T: Send + 'static,
    I: Send + 'static,
    F: Fn(I) -> T + Send + Sync + 'static,
{
    let pool = ThreadPool::new(workers.max(1));
    let n = items.len();
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let f = Arc::new(f);
    for (i, item) in items.into_iter().enumerate() {
        let results = Arc::clone(&results);
        let f = Arc::clone(&f);
        pool.execute(move || {
            let out = f(item);
            results.lock().unwrap()[i] = Some(out);
        });
    }
    pool.join();
    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("pool joined but results still shared"))
        .into_inner()
        .unwrap()
}

/// Returns the number of logical CPUs (parsed from /proc; fallback 4).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert_eq!(pool.inflight(), 0);
    }

    #[test]
    fn join_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join(); // must not hang
    }

    #[test]
    fn panics_are_contained() {
        let pool = ThreadPool::new(2);
        let ok = Arc::new(AtomicUsize::new(0));
        for i in 0..10 {
            let ok = Arc::clone(&ok);
            pool.execute(move || {
                if i % 2 == 0 {
                    panic!("boom {i}");
                }
                ok.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(ok.load(Ordering::SeqCst), 5);
        assert_eq!(pool.panic_count(), 5);
    }

    #[test]
    fn actually_parallel() {
        // With 4 workers, 4 jobs that each wait for the others to start
        // must all be running at once or this deadlocks (bounded by timeout).
        let pool = ThreadPool::new(4);
        let barrier = Arc::new(std::sync::Barrier::new(4));
        for _ in 0..4 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                b.wait();
            });
        }
        pool.join();
    }

    #[test]
    fn scope_run_preserves_order() {
        let out = scope_run(3, (0..50).collect::<Vec<u64>>(), |i| i * 2);
        let got: Vec<u64> = out.into_iter().map(|o| o.unwrap()).collect();
        assert_eq!(got, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_run_panics_become_none() {
        let out = scope_run(2, vec![1u64, 2, 3], |i| {
            if i == 2 {
                panic!("no");
            }
            i
        });
        assert_eq!(out[0], Some(1));
        assert_eq!(out[1], None);
        assert_eq!(out[2], Some(3));
    }

    #[test]
    fn reuse_after_join() {
        let pool = ThreadPool::new(2);
        let sum = Arc::new(AtomicU64::new(0));
        for round in 0..3u64 {
            for i in 0..10u64 {
                let s = Arc::clone(&sum);
                pool.execute(move || {
                    s.fetch_add(round * 10 + i, Ordering::SeqCst);
                });
            }
            pool.join();
        }
        let expected: u64 = (0..30u64).sum();
        assert_eq!(sum.load(Ordering::SeqCst), expected);
    }
}
