//! Deterministic pseudo-random number generation.
//!
//! The image has no `rand` crate, so Memento ships its own small PRNG:
//! [`Rng`] is xoshiro256++ seeded through SplitMix64 — fast, well-distributed,
//! and fully deterministic across platforms, which matters because synthetic
//! dataset generation and model initialization must be reproducible for the
//! cache/checkpoint tests to be meaningful.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Creates a generator from a 64-bit seed. Two generators with the same
    /// seed produce identical streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s, spare_normal: None }
    }

    /// Derives an independent child generator; used to give each task/fold
    /// its own stream without coupling to execution order.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
            // Retry on the (tiny) biased region; avoid retry when n divides 2^64.
            if n.is_power_of_two() {
                return (x & (n - 1)) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal deviate (Box-Muller, with caching of the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Rejection-free polar-less Box-Muller.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal deviate with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Samples `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn below_unbiased_roughly() {
        let mut r = Rng::new(4);
        let n = 120_000;
        let mut counts = [0usize; 6];
        for _ in 0..n {
            counts[r.below(6)] += 1;
        }
        for c in counts {
            let expected = n / 6;
            assert!(
                (c as i64 - expected as i64).abs() < (expected as i64) / 10,
                "count {c} vs expected {expected}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_gives_independent_streams() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
