//! Per-worker work queues with a steal path — the substrate under
//! [`crate::util::pool::ThreadPool`].
//!
//! # Why work-stealing
//!
//! The original pool funnelled every job through a single
//! `Mutex<VecDeque>`: with N workers and sub-microsecond jobs the queue
//! mutex becomes the whole program — every push and every pop from every
//! thread serializes on one cache line. Splitting the queue per worker
//! makes the common path (owner pushes/pops its own queue) contention-free
//! in practice: the only cross-thread traffic is *stealing*, which happens
//! exactly when a worker would otherwise idle, i.e. when the lock is cheap
//! because the owner is busy running a job, not queueing.
//!
//! # FIFO-fairness tradeoff
//!
//! Classic Chase-Lev deques pop LIFO at the owner end for cache locality.
//! We deliberately pop **FIFO** (front) at the owner and steal from the
//! **back**:
//!
//! - FIFO preserves submission order per worker, which keeps
//!   single-worker runs exactly sequential (a documented scheduler
//!   guarantee the tests pin down) and keeps progress/ETA smooth;
//! - owner (front) and thief (back) operate on opposite ends, so even
//!   under a mutex the two rarely want the same element;
//! - experiment tasks are milliseconds-to-hours, so the LIFO locality win
//!   is irrelevant here — fairness and predictability are worth more.
//!
//! The implementation is a `Mutex<VecDeque>` per queue rather than a
//! lock-free Chase-Lev ring: uncontended `Mutex` lock/unlock on Linux is a
//! pair of atomic ops (~20ns), far below per-task budget, and it keeps the
//! unsafe-code count at zero. The scheduler amortizes even that by pushing
//! *chunks* of tasks as single jobs (see [`crate::coordinator::scheduler`]).

use std::collections::VecDeque;
use std::sync::Mutex;

/// A single worker's queue. Owner ops use the front; thieves use the back.
/// The caller (the pool) does its own steal accounting — this type is just
/// the two-ended queue.
#[derive(Debug, Default)]
pub struct WorkQueue<T> {
    q: Mutex<VecDeque<T>>,
}

impl<T> WorkQueue<T> {
    /// Creates an empty queue.
    pub fn new() -> Self {
        WorkQueue { q: Mutex::new(VecDeque::new()) }
    }

    /// Appends one item at the back (submission order preserved for the
    /// owner's FIFO pops).
    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
    }

    /// Appends many items with a single lock acquisition.
    pub fn push_batch(&self, items: impl IntoIterator<Item = T>) {
        let mut q = self.q.lock().unwrap();
        q.extend(items);
    }

    /// Owner pop: oldest item first (FIFO).
    pub fn pop(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }

    /// Thief pop: newest item, from the opposite end to the owner.
    pub fn steal(&self) -> Option<T> {
        self.q.lock().unwrap().pop_back()
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.q.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn owner_pops_fifo() {
        let q = WorkQueue::new();
        for i in 0..5 {
            q.push(i);
        }
        let got: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn thief_steals_from_back() {
        let q = WorkQueue::new();
        for i in 0..4 {
            q.push(i);
        }
        assert_eq!(q.steal(), Some(3));
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.len(), 2);
        assert!(!q.is_empty());
    }

    #[test]
    fn batch_push_preserves_order() {
        let q = WorkQueue::new();
        q.push_batch(0..5);
        q.push_batch(5..8);
        let got: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_pop_and_steal_exactly_once() {
        // One owner popping, three thieves stealing; every item must be
        // taken exactly once.
        const N: u64 = 10_000;
        let q = Arc::new(WorkQueue::new());
        q.push_batch(0..N);
        let sum = Arc::new(AtomicU64::new(0));
        let count = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for role in 0..4 {
            let q = Arc::clone(&q);
            let sum = Arc::clone(&sum);
            let count = Arc::clone(&count);
            handles.push(std::thread::spawn(move || loop {
                let item = if role == 0 { q.pop() } else { q.steal() };
                match item {
                    Some(v) => {
                        sum.fetch_add(v, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                    None => break,
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Thieves may exit early on a momentarily-empty queue; drain rest.
        while let Some(v) = q.pop() {
            sum.fetch_add(v, Ordering::Relaxed);
            count.fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(count.load(Ordering::Relaxed), N);
        assert_eq!(sum.load(Ordering::Relaxed), N * (N - 1) / 2);
    }
}
