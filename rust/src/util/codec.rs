//! Compact tagged binary encoding of [`Json`] documents.
//!
//! Every persisted or wire-framed document in Memento used to be compact
//! JSON text; parsing it back builds a full [`Json`] tree even when the
//! reader wants one field. This module adds the binary half of the
//! format story: a tagged, length-prefixed encoding that is **lossless
//! with respect to the [`Json`] model** — `decode(encode(doc)) == doc`
//! for every document the JSON writer can produce — so the two formats
//! are interchangeable on every read path.
//!
//! # Layout
//!
//! A binary document is one [`BINARY_MAGIC`] byte followed by one value.
//! The magic byte (`0xB1`) can never begin a JSON document (it is not
//! ASCII and not a valid UTF-8 leading byte), which is what makes
//! per-payload auto-detection ([`is_binary`], [`read_document`]) safe:
//! readers accept both formats without negotiation.
//!
//! Each value is a 1-byte tag followed by its payload:
//!
//! | tag | value | payload |
//! |-----|-------|---------|
//! | `0x00` | null | — |
//! | `0x01` | false | — |
//! | `0x02` | true | — |
//! | `0x03` | integer | zigzag LEB128 varint (`i64`) |
//! | `0x04` | float | 8-byte little-endian IEEE-754 `f64` |
//! | `0x05` | string | varint byte length + UTF-8 bytes |
//! | `0x06` | array | varint element count + elements |
//! | `0x07` | object | varint entry count + (varint key length + key bytes + value) per entry |
//!
//! Numbers mirror the JSON writer's policy exactly: a finite `f64` with
//! no fractional part and magnitude below 9×10¹⁵ encodes as an integer
//! (tag `0x03`), everything else as a float, and NaN/infinity as null —
//! so a value round-tripped through *either* format compares equal.
//! Object entries are written in [`Json::Obj`]'s sorted key order, making
//! the encoding canonical like its JSON counterpart.
//!
//! The low-level varint/skip helpers are shared with the lazy field
//! scanner ([`crate::util::scan`]), which walks this layout without
//! materializing a tree.

use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::fmt;

/// First byte of every binary document. Not ASCII and not a valid UTF-8
/// leading byte, so no JSON text (which begins with `{`, `[`, `"`, a
/// digit, `-`, `t`, `f`, `n`, or whitespace) can collide with it.
pub const BINARY_MAGIC: u8 = 0xB1;

/// Value tag: JSON `null` (also NaN/infinity, mirroring the JSON writer).
pub const TAG_NULL: u8 = 0x00;
/// Value tag: boolean `false`.
pub const TAG_FALSE: u8 = 0x01;
/// Value tag: boolean `true`.
pub const TAG_TRUE: u8 = 0x02;
/// Value tag: exact integer, zigzag LEB128 varint payload.
pub const TAG_INT: u8 = 0x03;
/// Value tag: 8-byte little-endian `f64` payload.
pub const TAG_F64: u8 = 0x04;
/// Value tag: varint-length-prefixed UTF-8 string payload.
pub const TAG_STR: u8 = 0x05;
/// Value tag: varint-count-prefixed array payload.
pub const TAG_ARR: u8 = 0x06;
/// Value tag: varint-count-prefixed object payload (sorted keys).
pub const TAG_OBJ: u8 = 0x07;

/// Payload encoding for post-handshake IPC frames and for documents at
/// rest (cache entries, checkpoint manifests and progress files). Readers
/// always auto-detect per payload, so this only chooses what a *writer*
/// emits. Re-exported as `ipc::proto::WireFormat`, where the
/// supervisor/worker handshake negotiates it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    /// Compact JSON text — human-debuggable, and the only encoding
    /// pre-v3 peers (or pre-v3 on-disk stores) understand.
    Json,
    /// Compact tagged binary (this module) — the default since protocol
    /// v3.
    #[default]
    Binary,
}

impl WireFormat {
    /// Parses the CLI spelling (`"json"` / `"binary"`).
    pub fn parse_arg(s: &str) -> Option<WireFormat> {
        match s {
            "json" => Some(WireFormat::Json),
            "binary" => Some(WireFormat::Binary),
            _ => None,
        }
    }

    /// The canonical spelling, matching [`WireFormat::parse_arg`].
    pub fn as_str(self) -> &'static str {
        match self {
            WireFormat::Json => "json",
            WireFormat::Binary => "binary",
        }
    }
}

/// Serializes a document in the requested format: [`encode`] bytes for
/// [`WireFormat::Binary`], compact JSON text for [`WireFormat::Json`].
/// The inverse of [`read_document`] either way.
pub fn write_document(doc: &Json, format: WireFormat) -> Vec<u8> {
    match format {
        WireFormat::Binary => encode(doc),
        WireFormat::Json => doc.to_string().into_bytes(),
    }
}

/// Decode failure: what went wrong and at which byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecError {
    /// Human-readable description of the malformation.
    pub msg: String,
    /// Byte offset at which decoding failed.
    pub at: usize,
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "codec error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for CodecError {}

fn err(msg: impl Into<String>, at: usize) -> CodecError {
    CodecError { msg: msg.into(), at }
}

/// True when `bytes` starts with the binary document magic.
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.first() == Some(&BINARY_MAGIC)
}

/// Encodes a document: [`BINARY_MAGIC`] + one value.
pub fn encode(doc: &Json) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.push(BINARY_MAGIC);
    write_value(doc, &mut out);
    out
}

/// Decodes a binary document produced by [`encode`]. Trailing bytes after
/// the value are an error (a truncation guard in reverse: a concatenated
/// or corrupted buffer must not decode silently).
pub fn decode(bytes: &[u8]) -> Result<Json, CodecError> {
    if !is_binary(bytes) {
        return Err(err("missing binary magic byte", 0));
    }
    let mut pos = 1usize;
    let v = read_value(bytes, &mut pos, 0)?;
    if pos != bytes.len() {
        return Err(err(
            format!("{} trailing byte(s) after document", bytes.len() - pos),
            pos,
        ));
    }
    Ok(v)
}

/// Reads a document in **either** format: binary (magic byte) or UTF-8
/// JSON text. This is the storage read path's auto-detect — result
/// caches, checkpoint manifests, and progress files written by older
/// (JSON-only) builds stay loadable next to new binary entries.
pub fn read_document(bytes: &[u8]) -> Result<Json, CodecError> {
    if is_binary(bytes) {
        return decode(bytes);
    }
    let text = std::str::from_utf8(bytes).map_err(|e| err(format!("not utf-8: {e}"), 0))?;
    parse(text).map_err(|e| err(format!("not json: {e}"), 0))
}

/// Appends one encoded value (no magic byte) to `out`.
pub fn write_value(v: &Json, out: &mut Vec<u8>) {
    match v {
        Json::Null => out.push(TAG_NULL),
        Json::Bool(false) => out.push(TAG_FALSE),
        Json::Bool(true) => out.push(TAG_TRUE),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => {
            out.push(TAG_STR);
            write_varint(s.len() as u64, out);
            out.extend_from_slice(s.as_bytes());
        }
        Json::Arr(a) => {
            out.push(TAG_ARR);
            write_varint(a.len() as u64, out);
            for item in a {
                write_value(item, out);
            }
        }
        Json::Obj(o) => {
            out.push(TAG_OBJ);
            write_varint(o.len() as u64, out);
            for (k, item) in o {
                write_varint(k.len() as u64, out);
                out.extend_from_slice(k.as_bytes());
                write_value(item, out);
            }
        }
    }
}

/// Number policy shared with the JSON writer: exact small integers get
/// the varint encoding, NaN/infinity become null, the rest stay `f64`.
fn write_num(n: f64, out: &mut Vec<u8>) {
    if n.is_nan() || n.is_infinite() {
        out.push(TAG_NULL);
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push(TAG_INT);
        write_varint(zigzag(n as i64), out);
    } else {
        out.push(TAG_F64);
        out.extend_from_slice(&n.to_le_bytes());
    }
}

/// Decodes one value starting at `*pos`, advancing it past the value.
/// `depth` guards against adversarially nested input (same bound as the
/// JSON parser).
pub fn read_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, CodecError> {
    const MAX_DEPTH: usize = 128;
    if depth >= MAX_DEPTH {
        return Err(err("maximum nesting depth exceeded", *pos));
    }
    let tag = *bytes.get(*pos).ok_or_else(|| err("truncated: missing value tag", *pos))?;
    *pos += 1;
    match tag {
        TAG_NULL => Ok(Json::Null),
        TAG_FALSE => Ok(Json::Bool(false)),
        TAG_TRUE => Ok(Json::Bool(true)),
        TAG_INT => {
            let raw = read_varint(bytes, pos)?;
            Ok(Json::Num(unzigzag(raw) as f64))
        }
        TAG_F64 => {
            let end = pos.checked_add(8).filter(|&e| e <= bytes.len());
            let end = end.ok_or_else(|| err("truncated f64", *pos))?;
            let mut raw = [0u8; 8];
            raw.copy_from_slice(&bytes[*pos..end]);
            *pos = end;
            Ok(Json::Num(f64::from_le_bytes(raw)))
        }
        TAG_STR => Ok(Json::Str(read_string(bytes, pos)?)),
        TAG_ARR => {
            let count = read_varint(bytes, pos)? as usize;
            // Guard the pre-allocation: each element costs ≥ 1 byte, so a
            // count beyond the remaining buffer is corrupt.
            if count > bytes.len().saturating_sub(*pos) {
                return Err(err(format!("array count {count} exceeds input"), *pos));
            }
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(read_value(bytes, pos, depth + 1)?);
            }
            Ok(Json::Arr(items))
        }
        TAG_OBJ => {
            let count = read_varint(bytes, pos)? as usize;
            if count > bytes.len().saturating_sub(*pos) {
                return Err(err(format!("object count {count} exceeds input"), *pos));
            }
            let mut map = BTreeMap::new();
            for _ in 0..count {
                let key = read_string(bytes, pos)?;
                let val = read_value(bytes, pos, depth + 1)?;
                map.insert(key, val);
            }
            Ok(Json::Obj(map))
        }
        other => Err(err(format!("unknown value tag 0x{other:02x}"), *pos - 1)),
    }
}

fn read_string(bytes: &[u8], pos: &mut usize) -> Result<String, CodecError> {
    let len = read_varint(bytes, pos)? as usize;
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| err("truncated string", *pos))?;
    let s = std::str::from_utf8(&bytes[*pos..end])
        .map_err(|e| err(format!("string not utf-8: {e}"), *pos))?
        .to_string();
    *pos = end;
    Ok(s)
}

/// Appends an unsigned LEB128 varint.
pub fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Reads an unsigned LEB128 varint at `*pos`, advancing it.
pub fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64, CodecError> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let byte = *bytes.get(*pos).ok_or_else(|| err("truncated varint", *pos))?;
        *pos += 1;
        if shift == 63 && byte > 1 {
            return Err(err("varint overflows u64", *pos - 1));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(err("varint longer than 10 bytes", *pos - 1));
        }
    }
}

/// Zigzag-maps a signed integer to an unsigned varint payload so small
/// negative values stay short.
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Advances `*pos` past one encoded value **without** building any
/// [`Json`] node — the skip primitive the lazy scanner is built on.
/// Recursion depth is bounded like [`read_value`]'s, so adversarial
/// nesting errors out instead of exhausting the stack.
pub fn skip_value(bytes: &[u8], pos: &mut usize) -> Result<(), CodecError> {
    skip_value_depth(bytes, pos, 0)
}

fn skip_value_depth(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<(), CodecError> {
    const MAX_DEPTH: usize = 128;
    if depth >= MAX_DEPTH {
        return Err(err("maximum nesting depth exceeded", *pos));
    }
    let tag = *bytes.get(*pos).ok_or_else(|| err("truncated: missing value tag", *pos))?;
    *pos += 1;
    match tag {
        TAG_NULL | TAG_FALSE | TAG_TRUE => Ok(()),
        TAG_INT => read_varint(bytes, pos).map(|_| ()),
        TAG_F64 => {
            let end = pos
                .checked_add(8)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| err("truncated f64", *pos))?;
            *pos = end;
            Ok(())
        }
        TAG_STR => skip_len_prefixed(bytes, pos),
        TAG_ARR => {
            let count = read_varint(bytes, pos)?;
            for _ in 0..count {
                skip_value_depth(bytes, pos, depth + 1)?;
            }
            Ok(())
        }
        TAG_OBJ => {
            let count = read_varint(bytes, pos)?;
            for _ in 0..count {
                skip_len_prefixed(bytes, pos)?; // key
                skip_value_depth(bytes, pos, depth + 1)?;
            }
            Ok(())
        }
        other => Err(err(format!("unknown value tag 0x{other:02x}"), *pos - 1)),
    }
}

fn skip_len_prefixed(bytes: &[u8], pos: &mut usize) -> Result<(), CodecError> {
    let len = read_varint(bytes, pos)? as usize;
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= bytes.len())
        .ok_or_else(|| err("truncated length-prefixed payload", *pos))?;
    *pos = end;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(doc: Json) {
        let bytes = encode(&doc);
        assert!(is_binary(&bytes));
        assert_eq!(decode(&bytes).unwrap(), doc, "binary roundtrip of {doc}");
        // Format parity: the JSON text path must agree value-for-value.
        assert_eq!(parse(&doc.to_string()).unwrap(), decode(&bytes).unwrap());
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(Json::Null);
        roundtrip(Json::Bool(true));
        roundtrip(Json::Bool(false));
        roundtrip(Json::int(0));
        roundtrip(Json::int(1));
        roundtrip(Json::int(-1));
        roundtrip(Json::int(i64::MAX / 1024));
        roundtrip(Json::int(-(1 << 52)));
        roundtrip(Json::Num(0.5));
        roundtrip(Json::Num(-3.25e-9));
        roundtrip(Json::Num(9.0e15)); // just past the integer cutoff: stays f64
        roundtrip(Json::str(""));
        roundtrip(Json::str("héllo wörld 😀"));
        roundtrip(Json::str("quotes \" and \\ and \n newlines"));
    }

    #[test]
    fn nested_roundtrip() {
        roundtrip(Json::arr(vec![]));
        roundtrip(Json::obj(vec![]));
        roundtrip(Json::obj(vec![
            ("id", Json::str("abc")),
            (
                "params",
                Json::arr(vec![
                    Json::arr(vec![Json::str("lr"), Json::Num(0.01)]),
                    Json::arr(vec![Json::str("n"), Json::int(5)]),
                ]),
            ),
            (
                "value",
                Json::obj(vec![("accuracy", Json::Num(0.93)), ("folds", Json::int(10))]),
            ),
        ]));
    }

    #[test]
    fn nan_and_infinity_become_null_like_json() {
        for n in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = Json::arr(vec![Json::Num(n)]);
            assert_eq!(decode(&encode(&doc)).unwrap(), Json::arr(vec![Json::Null]));
            assert_eq!(parse(&doc.to_string()).unwrap(), Json::arr(vec![Json::Null]));
        }
    }

    #[test]
    fn varint_edges() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u64::MAX / 7, u64::MAX] {
            let mut out = Vec::new();
            write_varint(v, &mut out);
            let mut pos = 0;
            assert_eq!(read_varint(&out, &mut pos).unwrap(), v);
            assert_eq!(pos, out.len());
        }
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        // 11-byte continuation run overflows.
        let bad = [0x80u8; 11];
        assert!(read_varint(&bad, &mut 0).is_err());
    }

    /// Randomized documents via the in-tree RNG: binary↔JSON parity on
    /// arbitrary trees, not just hand-picked shapes.
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        let scalar_only = depth >= 3;
        match rng.below(if scalar_only { 5 } else { 7 }) {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::int(rng.next_u64() as i64 >> 12),
            3 => Json::Num(rng.normal_ms(0.0, 1.0e4)),
            4 => {
                let len = rng.below(12);
                Json::Str((0..len).map(|_| rng.choice(&['a', 'é', '😀', '"', '\\', '\n'])).collect())
            }
            5 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{}{}", i, rng.below(100)), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn randomized_documents_roundtrip_in_both_formats() {
        let mut rng = Rng::new(0xC0DEC);
        for _ in 0..500 {
            let doc = random_json(&mut rng, 0);
            let bin = decode(&encode(&doc)).unwrap();
            let txt = parse(&doc.to_string()).unwrap();
            assert_eq!(bin, txt, "format divergence on {doc}");
        }
    }

    #[test]
    fn read_document_auto_detects() {
        let doc = Json::obj(vec![("x", Json::int(7))]);
        assert_eq!(read_document(&encode(&doc)).unwrap(), doc);
        assert_eq!(read_document(doc.to_string().as_bytes()).unwrap(), doc);
        assert!(read_document(b"{ not json").is_err());
        assert!(read_document(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn truncated_and_corrupt_inputs_error() {
        let full = encode(&Json::obj(vec![
            ("a", Json::str("hello")),
            ("b", Json::arr(vec![Json::int(1), Json::Num(0.5)])),
        ]));
        // Every prefix of a valid document must fail cleanly.
        for cut in 1..full.len() {
            assert!(decode(&full[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        // Trailing garbage is rejected.
        let mut extended = full.clone();
        extended.push(0x00);
        assert!(decode(&extended).is_err());
        // Unknown tag.
        assert!(decode(&[BINARY_MAGIC, 0x77]).is_err());
        // Absurd collection count cannot pre-allocate.
        let mut bomb = vec![BINARY_MAGIC, TAG_ARR];
        write_varint(u32::MAX as u64, &mut bomb);
        assert!(decode(&bomb).is_err());
        // Missing magic.
        assert!(decode(&full[1..]).is_err());
    }

    #[test]
    fn deep_nesting_is_bounded() {
        let mut bytes = vec![BINARY_MAGIC];
        for _ in 0..200 {
            bytes.push(TAG_ARR);
            bytes.push(1); // one element
        }
        bytes.push(TAG_NULL);
        assert!(decode(&bytes).is_err());
        assert!(skip_value(&bytes[1..], &mut 0).is_err());
    }

    #[test]
    fn skip_matches_read() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let doc = random_json(&mut rng, 0);
            let bytes = encode(&doc);
            let mut read_pos = 1;
            read_value(&bytes, &mut read_pos, 0).unwrap();
            let mut skip_pos = 1;
            skip_value(&bytes, &mut skip_pos).unwrap();
            assert_eq!(read_pos, skip_pos, "skip length mismatch on {doc}");
            assert_eq!(read_pos, bytes.len());
        }
    }

    #[test]
    fn integral_floats_collapse_to_ints_in_both_formats() {
        // 3.0 written as f64 must decode equal to 3 written as int — the
        // writers normalize, so equality falls out of f64 comparison.
        let a = decode(&encode(&Json::Num(3.0))).unwrap();
        let b = decode(&encode(&Json::int(3))).unwrap();
        assert_eq!(a, b);
        // And the binary encodings are byte-identical (canonical form).
        assert_eq!(encode(&Json::Num(3.0)), encode(&Json::int(3)));
    }
}
