//! Declarative command-line parsing (offline `clap` replacement).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, typed
//! accessors with defaults, positional arguments, and generated `--help`
//! text. Only what the `memento` binary and the examples need.

use std::collections::BTreeMap;
use std::fmt;

/// Specification of one option/flag.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long option name (without the `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Flags take no value; options take exactly one.
    pub is_flag: bool,
    /// Default value for options; `None` = absent unless provided.
    pub default: Option<&'static str>,
}

/// Parser specification: a name, blurb, options, and positional names.
#[derive(Debug, Clone, Default)]
pub struct CliSpec {
    /// Command name shown in usage/help.
    pub name: &'static str,
    /// One-line description shown in help.
    pub about: &'static str,
    /// Declared options and flags.
    pub opts: Vec<OptSpec>,
    /// Declared positional arguments as `(name, help)` pairs.
    pub positionals: Vec<(&'static str, &'static str)>,
}

impl CliSpec {
    /// A new empty spec for the named command.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        CliSpec { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    /// Declares a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    /// Declares a `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default: Some(default) });
        self
    }

    /// An option with no default: `get` returns `None` when absent.
    pub fn opt_required(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default: None });
        self
    }

    /// Declares the next positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Renders `--help` output.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let head = if o.is_flag {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <value>", o.name)
                };
                let dflt = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {head:<24} {}{}\n", o.help, dflt));
            }
        }
        s
    }

    /// Parses an argument vector (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<CliArgs, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        let mut positionals = Vec::new();

        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.to_string(), false);
            } else if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError::HelpRequested(self.help()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::UnknownOption(name.to_string()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(CliError::FlagWithValue(name.to_string()));
                    }
                    flags.insert(name.to_string(), true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.to_string()))?,
                    };
                    values.insert(name.to_string(), v);
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        if positionals.len() > self.positionals.len() {
            return Err(CliError::TooManyPositionals(positionals.len(), self.positionals.len()));
        }
        Ok(CliArgs { values, flags, positionals, spec_positionals: self.positionals.clone() })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Clone)]
pub struct CliArgs {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
    spec_positionals: Vec<(&'static str, &'static str)>,
}

impl CliArgs {
    /// The option's value (provided or default); `None` when absent.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// The option's value parsed as `usize`.
    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        s.parse()
            .map_err(|_| CliError::BadValue(name.to_string(), s.to_string(), "usize"))
    }

    /// The option's value parsed as `f64`.
    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        s.parse()
            .map_err(|_| CliError::BadValue(name.to_string(), s.to_string(), "f64"))
    }

    /// The option's value parsed as `u64`.
    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        let s = self
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        s.parse()
            .map_err(|_| CliError::BadValue(name.to_string(), s.to_string(), "u64"))
    }

    /// True when the flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Positional by declared name.
    pub fn pos(&self, name: &str) -> Option<&str> {
        let idx = self.spec_positionals.iter().position(|(n, _)| *n == name)?;
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

/// CLI parsing errors (`HelpRequested` carries the rendered help text).
#[derive(Debug, Clone)]
pub enum CliError {
    /// `--help` was passed; carries the rendered help text.
    HelpRequested(String),
    /// An option not declared in the spec.
    UnknownOption(String),
    /// An option that requires a value had none.
    MissingValue(String),
    /// A flag was given an `=value`.
    FlagWithValue(String),
    /// A value failed to parse as the requested type (option, raw value,
    /// type name).
    BadValue(String, String, &'static str),
    /// More positional arguments than the spec declares (got, max).
    TooManyPositionals(usize, usize),
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::HelpRequested(h) => write!(f, "{h}"),
            CliError::UnknownOption(n) => write!(f, "unknown option --{n}"),
            CliError::MissingValue(n) => write!(f, "option --{n} requires a value"),
            CliError::FlagWithValue(n) => write!(f, "flag --{n} does not take a value"),
            CliError::BadValue(n, v, ty) => {
                write!(f, "option --{n}: '{v}' is not a valid {ty}")
            }
            CliError::TooManyPositionals(got, want) => {
                write!(f, "expected at most {want} positional arguments, got {got}")
            }
        }
    }
}

impl std::error::Error for CliError {}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new("test", "a test")
            .opt("workers", "4", "worker count")
            .opt_required("out", "output path")
            .flag("verbose", "talk more")
            .positional("config", "config file")
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("workers"), Some("4"));
        assert_eq!(a.get_usize("workers").unwrap(), 4);
        assert_eq!(a.get("out"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let a = spec()
            .parse(&argv(&["--workers", "8", "--out=res.json", "--verbose", "cfg.json"]))
            .unwrap();
        assert_eq!(a.get_usize("workers").unwrap(), 8);
        assert_eq!(a.get("out"), Some("res.json"));
        assert!(a.flag("verbose"));
        assert_eq!(a.pos("config"), Some("cfg.json"));
    }

    #[test]
    fn rejects_unknown_and_bad() {
        assert!(matches!(
            spec().parse(&argv(&["--nope"])),
            Err(CliError::UnknownOption(_))
        ));
        assert!(matches!(
            spec().parse(&argv(&["--workers"])),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(
            spec().parse(&argv(&["--verbose=yes"])),
            Err(CliError::FlagWithValue(_))
        ));
        let a = spec().parse(&argv(&["--workers", "abc"])).unwrap();
        assert!(matches!(a.get_usize("workers"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn help_contains_everything() {
        let h = spec().help();
        for needle in ["--workers", "--out", "--verbose", "<config>", "a test"] {
            assert!(h.contains(needle), "help missing {needle}: {h}");
        }
        assert!(matches!(
            spec().parse(&argv(&["--help"])),
            Err(CliError::HelpRequested(_))
        ));
    }

    #[test]
    fn too_many_positionals() {
        assert!(matches!(
            spec().parse(&argv(&["a", "b"])),
            Err(CliError::TooManyPositionals(2, 1))
        ));
    }
}
