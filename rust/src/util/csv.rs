//! Minimal CSV reader/writer (substrate — no csv crate offline).
//!
//! Supports: comma separation, double-quote quoting with `""` escapes,
//! embedded newlines inside quoted fields, CRLF/LF line endings, and an
//! optional header row. Enough to load real tabular datasets into
//! [`crate::ml::data::Dataset`] and to export result tables.

use std::fmt;

/// A parsed CSV document: optional header + rows of string fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsvTable {
    /// Column names, when the document was parsed with a header row.
    pub header: Option<Vec<String>>,
    /// Data records, one `Vec<String>` of fields per row.
    pub rows: Vec<Vec<String>>,
}

/// CSV parse error with 1-based record index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CsvError {
    /// 1-based index of the offending record.
    pub record: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for CsvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "csv error at record {}: {}", self.record, self.msg)
    }
}

impl std::error::Error for CsvError {}

/// Parses CSV text. `has_header` pops the first record into `header`.
pub fn parse(text: &str, has_header: bool) -> Result<CsvTable, CsvError> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut field = String::new();
    let mut row: Vec<String> = Vec::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut record_no = 1;

    macro_rules! end_field {
        () => {{
            row.push(std::mem::take(&mut field));
        }};
    }
    macro_rules! end_row {
        () => {{
            end_field!();
            rows.push(std::mem::take(&mut row));
            record_no += 1;
        }};
    }

    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        return Err(CsvError {
                            record: record_no,
                            msg: "quote inside unquoted field".into(),
                        });
                    }
                    in_quotes = true;
                }
                ',' => end_field!(),
                '\r' => {
                    if chars.peek() == Some(&'\n') {
                        chars.next();
                    }
                    end_row!();
                }
                '\n' => end_row!(),
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err(CsvError { record: record_no, msg: "unterminated quoted field".into() });
    }
    // Trailing record without newline.
    if !field.is_empty() || !row.is_empty() {
        end_row!();
    }
    let _ = record_no; // final value only matters for error positions above
    // Drop fully-empty trailing rows (common from trailing newlines).
    while rows.last().map(|r| r.len() == 1 && r[0].is_empty()).unwrap_or(false) {
        rows.pop();
    }

    // Rectangularity check.
    if let Some(w) = rows.first().map(|r| r.len()) {
        for (i, r) in rows.iter().enumerate() {
            if r.len() != w {
                return Err(CsvError {
                    record: i + 1,
                    msg: format!("expected {w} fields, found {}", r.len()),
                });
            }
        }
    }

    let mut table = CsvTable { header: None, rows };
    if has_header && !table.rows.is_empty() {
        table.header = Some(table.rows.remove(0));
    }
    Ok(table)
}

/// Serializes rows (quoting only where needed).
pub fn write(table: &CsvTable) -> String {
    let mut out = String::new();
    let write_row = |row: &[String], out: &mut String| {
        for (i, f) in row.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            if f.contains([',', '"', '\n', '\r']) {
                out.push('"');
                out.push_str(&f.replace('"', "\"\""));
                out.push('"');
            } else {
                out.push_str(f);
            }
        }
        out.push('\n');
    };
    if let Some(h) = &table.header {
        write_row(h, &mut out);
    }
    for r in &table.rows {
        write_row(r, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn basic_parse_with_header() {
        let t = parse("a,b,c\n1,2,3\n4,5,6\n", true).unwrap();
        assert_eq!(t.header, Some(s(&["a", "b", "c"])));
        assert_eq!(t.rows, vec![s(&["1", "2", "3"]), s(&["4", "5", "6"])]);
    }

    #[test]
    fn quoting_and_escapes() {
        let t = parse("\"a,b\",\"say \"\"hi\"\"\",\"line\nbreak\"\n", false).unwrap();
        assert_eq!(t.rows[0], s(&["a,b", "say \"hi\"", "line\nbreak"]));
    }

    #[test]
    fn crlf_and_missing_trailing_newline() {
        let t = parse("1,2\r\n3,4", false).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[1], s(&["3", "4"]));
    }

    #[test]
    fn ragged_rows_error() {
        let e = parse("1,2\n3\n", false).unwrap_err();
        assert!(e.msg.contains("expected 2 fields"), "{e}");
        assert_eq!(e.record, 2);
    }

    #[test]
    fn unterminated_quote_errors() {
        assert!(parse("\"abc", false).is_err());
        assert!(parse("x\"y,z\n", false).is_err());
    }

    #[test]
    fn roundtrip() {
        let t = CsvTable {
            header: Some(s(&["name", "value"])),
            rows: vec![s(&["plain", "1"]), s(&["with,comma", "q\"uote"])],
        };
        let text = write(&t);
        let back = parse(&text, true).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn empty_input() {
        let t = parse("", false).unwrap();
        assert!(t.rows.is_empty());
        let t = parse("\n\n", false).unwrap();
        assert!(t.rows.is_empty());
    }
}
