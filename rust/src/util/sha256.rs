//! From-scratch SHA-256 (FIPS 180-4), replacing the external `sha2` crate
//! the image cannot fetch.
//!
//! Task identity (cache keys, checkpoint manifests, matrix fingerprints)
//! only needs a *stable, collision-resistant* content hash — no secrecy, no
//! HMAC — so a straightforward single-block-at-a-time implementation is
//! plenty: hashing a task's canonical JSON (~100 bytes) is nanoseconds next
//! to the experiment it identifies. The streaming [`Sha256`] API mirrors the
//! `sha2` crate's (`new` / `update` / `finalize`) so call sites read the
//! same.

/// Round constants: first 32 bits of the fractional parts of the cube roots
/// of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: first 32 bits of the fractional parts of the square
/// roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
    0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 hasher.
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block awaiting compression.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding encodes it in bits).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// A fresh hasher in the initial state.
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total_len: 0 }
    }

    /// Absorbs `data`; may be called repeatedly.
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            compress(&mut self.state, &block);
            data = &data[64..];
        }
        // Stash the tail.
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Pads, compresses the final block(s), and returns the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // 0x80 terminator, zeros, then the 64-bit big-endian bit length.
        let mut pad = [0u8; 128];
        pad[0] = 0x80;
        // Pad to 56 mod 64.
        let pad_len = if self.buf_len < 56 { 56 - self.buf_len } else { 120 - self.buf_len };
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        self.update(&pad[..pad_len + 8]);
        debug_assert_eq!(self.buf_len, 0, "padding must end on a block boundary");
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }
}

/// One compression round over a 64-byte block.
fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for (i, chunk) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(big_s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = big_s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// One-shot digest.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(bytes);
    h.finalize()
}

/// One-shot lowercase-hex digest (64 chars).
pub fn sha256_hex(bytes: &[u8]) -> String {
    let digest = sha256(bytes);
    let mut s = String::with_capacity(64);
    for b in digest {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

const HEX: &[u8; 16] = b"0123456789abcdef";

/// Constant-time secret equality: both inputs are reduced to fixed-length
/// digests and compared by XOR-folding every byte, so the comparison's
/// timing depends on neither the length nor the content of either input
/// (a direct `==` on the strings short-circuits at the first differing
/// byte, leaking how much of a guessed token matched). For comparing
/// secrets such as auth tokens — not a substitute for hashing.
pub fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    let (da, db) = (sha256(a), sha256(b));
    da.iter().zip(db.iter()).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST CAVP reference vectors.
    #[test]
    fn empty_input() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn abc_vector() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn constant_time_eq_matches_plain_equality() {
        assert!(constant_time_eq(b"", b""));
        assert!(constant_time_eq(b"secret-token", b"secret-token"));
        assert!(!constant_time_eq(b"secret-token", b"secret-tokem"));
        assert!(!constant_time_eq(b"secret-token", b"secret-token-longer"));
        assert!(!constant_time_eq(b"", b"x"));
    }

    #[test]
    fn two_block_vector() {
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a_vector() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(chunk);
        }
        let hex: String = h
            .finalize()
            .iter()
            .map(|b| format!("{b:02x}"))
            .collect();
        assert_eq!(
            hex,
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        // Split at awkward boundaries (partial blocks, exact blocks).
        for split in [0usize, 1, 55, 56, 63, 64, 65, 128, 999] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize(), sha256(&data), "split at {split}");
        }
    }

    #[test]
    fn padding_boundary_lengths() {
        // Lengths around the 56-byte padding threshold must all differ and
        // be stable; compare against length-extension-free recompute.
        let mut seen = std::collections::BTreeSet::new();
        for len in 0..130usize {
            let data = vec![0xabu8; len];
            let d1 = sha256(&data);
            let d2 = sha256(&data);
            assert_eq!(d1, d2);
            seen.insert(d1);
        }
        assert_eq!(seen.len(), 130, "all lengths hash distinctly");
    }

    #[test]
    fn hex_shape() {
        let h = sha256_hex(b"xyz");
        assert_eq!(h.len(), 64);
        assert!(h.chars().all(|c| c.is_ascii_hexdigit()));
    }
}
