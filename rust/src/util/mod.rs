//! Support substrates built from scratch for the offline image: JSON,
//! RNG, SHA-256, work-stealing thread pool, CLI parsing, filesystem
//! atomicity, and timing.

pub mod cli;
pub mod codec;
pub mod crc32;
pub mod csv;
pub mod deque;
pub mod fs;
pub mod json;
pub mod pool;
pub mod rng;
pub mod scan;
pub mod sha256;
pub mod time;
