//! Support substrates built from scratch for the offline image: JSON,
//! RNG, thread pool, CLI parsing, filesystem atomicity, and timing.

pub mod cli;
pub mod csv;
pub mod fs;
pub mod json;
pub mod pool;
pub mod rng;
pub mod time;
