//! Filesystem helpers with crash-consistency guarantees.
//!
//! Checkpoints and cache entries must never be observed half-written: a
//! power cut mid-`write` would otherwise corrupt the very state Memento
//! relies on to resume. All persistent writes go through
//! [`atomic_write`] (write temp file in the same directory, fsync, rename,
//! fsync the directory so the rename itself survives a power cut).

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Atomically replaces `path` with `contents`.
///
/// The write happens to a unique temporary file in the same directory
/// followed by `rename(2)`, which POSIX guarantees is atomic on the same
/// filesystem; readers see either the old or the new file, never a mix.
pub fn atomic_write(path: &Path, contents: &[u8]) -> io::Result<()> {
    atomic_write_opts(path, contents, true)
}

/// [`atomic_write`] without the fsync — still atomic w.r.t. concurrent
/// readers (tmp + rename), but a power cut may lose the entry entirely.
/// Appropriate for *recomputable* data (cache entries): a lost entry is a
/// cache miss, never corruption.
pub fn atomic_write_nosync(path: &Path, contents: &[u8]) -> io::Result<()> {
    atomic_write_opts(path, contents, false)
}

/// Fsyncs a directory so preceding renames/unlinks within it are durable.
///
/// `rename(2)` updates the *directory*, not the file: syncing only the
/// file leaves the new name itself volatile, and a power cut can roll the
/// directory back to the old entry. On Unix a directory can be opened and
/// `fsync`ed like a file; elsewhere this is a no-op (no portable
/// equivalent exists, and the platforms we ship to are Unix).
pub fn sync_dir(dir: &Path) -> io::Result<()> {
    #[cfg(unix)]
    {
        fs::File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
        Ok(())
    }
}

fn atomic_write_opts(path: &Path, contents: &[u8], durable: bool) -> io::Result<()> {
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    fs::create_dir_all(dir)?;
    let unique = format!(
        ".{}.tmp.{}.{}",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("file"),
        std::process::id(),
        TMP_COUNTER.fetch_add(1, Ordering::Relaxed),
    );
    let tmp = dir.join(unique);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(contents)?;
        if durable {
            f.sync_all()?;
        }
    }
    match fs::rename(&tmp, path) {
        Ok(()) => {
            // Durability gap without this: the file's bytes are synced but
            // the rename that *names* them lives only in the directory's
            // in-memory state until the directory itself is fsynced.
            if durable {
                sync_dir(dir)?;
            }
            Ok(())
        }
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// Reads a whole file to a string.
pub fn read_string(path: &Path) -> io::Result<String> {
    fs::read_to_string(path)
}

/// Lists files (not dirs) in `dir` with the given extension, sorted by name.
pub fn list_files_with_ext(dir: &Path, ext: &str) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_file() && p.extension().and_then(|e| e.to_str()) == Some(ext) {
            out.push(p);
        }
    }
    out.sort();
    Ok(out)
}

/// A unique temporary directory that is removed on drop. Used pervasively
/// by tests and benches for isolated cache/checkpoint stores.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Creates `std::env::temp_dir()/memento-<label>-<pid>-<n>`.
    pub fn new(label: &str) -> io::Result<TempDir> {
        let n = TMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        // Nanosecond component makes collisions across processes (e.g. a
        // leaked dir from a killed test run) practically impossible.
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "memento-{label}-{}-{n}-{nanos}",
            std::process::id()
        ));
        fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// A path inside the temp dir.
    pub fn join(&self, rel: &str) -> PathBuf {
        self.path.join(rel)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_write_roundtrip() {
        let td = TempDir::new("fs-test").unwrap();
        let p = td.join("a/b/c.json");
        atomic_write(&p, b"{\"x\":1}").unwrap();
        assert_eq!(read_string(&p).unwrap(), "{\"x\":1}");
        // Overwrite
        atomic_write(&p, b"{\"x\":2}").unwrap();
        assert_eq!(read_string(&p).unwrap(), "{\"x\":2}");
        // No stray temp files left behind.
        let leftovers: Vec<_> = fs::read_dir(p.parent().unwrap())
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn tempdir_cleanup() {
        let path;
        {
            let td = TempDir::new("cleanup").unwrap();
            path = td.path().to_path_buf();
            atomic_write(&td.join("f.txt"), b"x").unwrap();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn list_files_filters_and_sorts() {
        let td = TempDir::new("list").unwrap();
        atomic_write(&td.join("b.json"), b"{}").unwrap();
        atomic_write(&td.join("a.json"), b"{}").unwrap();
        atomic_write(&td.join("c.txt"), b"x").unwrap();
        let files = list_files_with_ext(td.path(), "json").unwrap();
        let names: Vec<_> = files
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["a.json", "b.json"]);
        // Missing dir is empty, not an error.
        assert!(list_files_with_ext(&td.join("nope"), "json").unwrap().is_empty());
    }
}
