//! Worker-process side of the process-isolated backend.
//!
//! A worker is the *same binary* as the supervisor, re-executed with two
//! environment variables set: [`ENV_SOCKET`] (the supervisor's Unix domain
//! socket) and [`ENV_WORKER_ID`] (this worker's slot number). Three entry
//! points cover the three kinds of host binary:
//!
//! - the `memento` CLI dispatches its hidden `worker` subcommand here;
//! - library binaries (examples, user programs) are intercepted inside
//!   [`crate::coordinator::memento::Memento::run`]: when the env vars are
//!   present, `run` serves tasks over the socket and exits instead of
//!   starting a run of its own — so a binary that re-executes itself needs
//!   no worker-specific code at all;
//! - test binaries expose a dedicated libtest entry (a `#[test]` fn that
//!   is a no-op without the env vars) and pass its name as the spawn argv.
//!
//! The worker executes **one attempt per `Task` frame** and reports the
//! raw result; retries, requeues, and crash accounting belong to the
//! supervisor. A heartbeat thread shares the write half of the socket so
//! the supervisor can distinguish "long-running task" from "hung worker".

use crate::coordinator::error::{panic_message, MementoError};
use crate::coordinator::memento::ExpFn;
use crate::coordinator::task::{task_seed, TaskContext, TaskId};
use crate::ipc::proto::{read_frame, write_frame, Msg, WireResult, PROTOCOL_VERSION};
use crate::util::json::Json;
use crate::util::time::Stopwatch;
use std::collections::BTreeMap;
use std::os::unix::net::UnixStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Socket path of the supervising process; presence of this variable is
/// what makes a process a worker.
pub const ENV_SOCKET: &str = "MEMENTO_WORKER_SOCKET";
/// Slot id assigned by the supervisor (`0..workers`).
pub const ENV_WORKER_ID: &str = "MEMENTO_WORKER_ID";
/// Spawn generation within the slot; echoed back in the `Ready` handshake
/// so the supervisor can tell a fresh worker's connection from a stale
/// (already-replaced) incarnation's.
pub const ENV_WORKER_SPAWN: &str = "MEMENTO_WORKER_SPAWN";

/// True when this process was spawned as a worker by a supervisor.
pub fn active() -> bool {
    std::env::var_os(ENV_SOCKET).is_some()
}

/// If this process is a worker, serve tasks until shutdown and then
/// **exit the process**; otherwise return immediately. Call this early in
/// a binary that re-executes itself for process isolation.
pub fn maybe_serve(exp_fn: Arc<ExpFn>) {
    if !active() {
        return;
    }
    match serve(exp_fn) {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            eprintln!("memento worker: {e}");
            std::process::exit(70); // EX_SOFTWARE
        }
    }
}

/// Connects to the supervisor named by the environment and serves task
/// attempts until it sends `Shutdown` (or closes the connection). Returns
/// once the connection is drained; callers normally exit afterwards.
pub fn serve(exp_fn: Arc<ExpFn>) -> Result<(), MementoError> {
    let socket = std::env::var(ENV_SOCKET)
        .map_err(|_| MementoError::ipc(format!("{ENV_SOCKET} not set")))?;
    let worker_id: u64 = std::env::var(ENV_WORKER_ID)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let spawn: u64 = std::env::var(ENV_WORKER_SPAWN)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);

    let stream = UnixStream::connect(&socket)
        .map_err(|e| MementoError::ipc(format!("connect {socket}: {e}")))?;
    let mut reader = stream
        .try_clone()
        .map_err(|e| MementoError::ipc(format!("clone stream: {e}")))?;
    let writer = Arc::new(Mutex::new(stream));

    send(
        &writer,
        &Msg::Ready { worker: worker_id, pid: std::process::id() as u64, spawn },
    )?;

    // First frame must be the run configuration.
    let hello = read_frame(&mut reader)
        .map_err(|e| MementoError::ipc(format!("read hello: {e}")))?
        .ok_or_else(|| MementoError::ipc("supervisor closed before hello"))?;
    let Msg::Hello { protocol, version, run_seed, settings, heartbeat_ms } = hello else {
        return Err(MementoError::ipc("expected hello as first frame"));
    };
    if protocol != PROTOCOL_VERSION {
        return Err(MementoError::ipc(format!(
            "protocol mismatch: supervisor speaks v{protocol}, worker speaks v{PROTOCOL_VERSION}"
        )));
    }
    let settings = Arc::new(settings);

    // Heartbeat thread: shares the writer; `busy` mirrors the task index
    // currently executing (-1 = idle) so the supervisor can tell a slow
    // task from a wedged worker. Heartbeats flow **only while busy**: the
    // supervisor reads the stream only while an attempt is in flight, so
    // idle heartbeats would accumulate unread in the socket buffer — and
    // a filled buffer would block this thread inside `write` holding the
    // writer lock, wedging the worker (and the supervisor's final
    // `child.wait()`) forever. Idle liveness needs no signal: a dead idle
    // worker is detected by the next task dispatch failing.
    let busy = Arc::new(AtomicI64::new(-1));
    let stop = Arc::new(AtomicBool::new(false));
    let hb_handle = spawn_heartbeat(
        Arc::clone(&writer),
        worker_id,
        Arc::clone(&busy),
        Arc::clone(&stop),
        Duration::from_millis(heartbeat_ms.max(1)),
    );

    let served = serve_loop(
        &mut reader,
        &writer,
        &exp_fn,
        &settings,
        &version,
        run_seed,
        &busy,
    );

    stop.store(true, Ordering::SeqCst);
    let _ = hb_handle.join();
    served
}

fn serve_loop(
    reader: &mut UnixStream,
    writer: &Arc<Mutex<UnixStream>>,
    exp_fn: &Arc<ExpFn>,
    settings: &Arc<BTreeMap<String, Json>>,
    version: &str,
    run_seed: u64,
    busy: &Arc<AtomicI64>,
) -> Result<(), MementoError> {
    loop {
        let msg = read_frame(reader).map_err(|e| MementoError::ipc(format!("read task: {e}")))?;
        match msg {
            None | Some(Msg::Shutdown) => return Ok(()),
            Some(Msg::Task { index, attempt, params, restored }) => {
                busy.store(index as i64, Ordering::SeqCst);
                let outcome = run_attempt(
                    writer, exp_fn, settings, version, run_seed, index, attempt, params, restored,
                );
                busy.store(-1, Ordering::SeqCst);
                send(writer, &outcome)?;
            }
            Some(other) => {
                return Err(MementoError::ipc(format!(
                    "unexpected frame from supervisor: {other:?}"
                )));
            }
        }
    }
}

/// Executes one attempt and builds its `Outcome` frame. Panics in the
/// experiment function are contained here, exactly as the thread backend
/// contains them — only failures *of the process itself* reach the
/// supervisor as crashes.
#[allow(clippy::too_many_arguments)]
fn run_attempt(
    writer: &Arc<Mutex<UnixStream>>,
    exp_fn: &Arc<ExpFn>,
    settings: &Arc<BTreeMap<String, Json>>,
    version: &str,
    run_seed: u64,
    index: u64,
    attempt: u64,
    params: Vec<(String, crate::config::value::ParamValue)>,
    restored: Option<Json>,
) -> Msg {
    let spec = Msg::task_spec(index, &params);
    let id = spec.id(version);
    let seed = task_seed(run_seed, &id);

    // Partial progress is relayed to the supervisor, which persists it in
    // the checkpoint store — the worker never touches the store directly.
    let w2 = Arc::clone(writer);
    let sink: Arc<dyn Fn(&TaskId, &Json) + Send + Sync> = Arc::new(move |_tid, value| {
        let _ = send(&w2, &Msg::Progress { index, value: value.clone() });
    });

    let ctx = TaskContext::new(
        spec,
        Arc::clone(settings),
        seed,
        attempt as u32,
        id,
        restored,
        Some(sink),
    );
    let sw = Stopwatch::start();
    let result = match catch_unwind(AssertUnwindSafe(|| exp_fn(&ctx))) {
        Ok(Ok(value)) => WireResult::Ok { value },
        Ok(Err(e)) => WireResult::Err { message: e.to_string(), panicked: false },
        Err(payload) => WireResult::Err {
            message: panic_message(payload.as_ref()),
            panicked: true,
        },
    };
    Msg::Outcome { index, attempt, duration_secs: sw.elapsed_secs(), result }
}

fn send(writer: &Arc<Mutex<UnixStream>>, msg: &Msg) -> Result<(), MementoError> {
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, msg).map_err(|e| MementoError::ipc(format!("write frame: {e}")))
}

fn spawn_heartbeat(
    writer: Arc<Mutex<UnixStream>>,
    worker: u64,
    busy: Arc<AtomicI64>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("memento-ipc-heartbeat".into())
        .spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(interval);
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                let b = busy.load(Ordering::SeqCst);
                if b < 0 {
                    continue; // idle: nobody is reading, don't fill the pipe
                }
                let msg = Msg::Heartbeat { worker, busy: Some(b as u64) };
                if send(&writer, &msg).is_err() {
                    // Supervisor is gone; the serve loop will notice on its
                    // next read. Nothing useful left to do here.
                    return;
                }
            }
        })
        .expect("spawn heartbeat thread")
}
