//! Worker side of the process-isolated and distributed backends.
//!
//! Two kinds of worker speak the same protocol over the same code path:
//!
//! - **Spawned workers** (`--isolation process`): re-executions of the
//!   current binary with [`ENV_SOCKET`]/[`ENV_WORKER_ID`] set, connected
//!   to a private Unix socket, serving exactly one run and exiting. Three
//!   entry points cover the three kinds of host binary: the `memento` CLI
//!   dispatches its hidden `worker` subcommand here; library binaries are
//!   intercepted inside [`crate::coordinator::memento::Memento::run`]
//!   (when the env vars are present, `run` serves tasks and exits, so a
//!   self-re-executing binary needs no worker code); test binaries expose
//!   a dedicated libtest entry and pass its name as the spawn argv.
//! - **Standing remote workers** (`memento serve`, or [`serve_remote`]
//!   from a library): long-lived processes that *connect out* to a
//!   supervisor's TCP [`crate::ipc::pool::WorkerPool`], authenticate with
//!   a shared token, serve a run, and — instead of exiting at `Shutdown`
//!   — reconnect and re-register for the next run. A dropped connection
//!   (supervisor restart, network blip) is retried with exponential
//!   backoff, so a worker that drops mid-run rejoins the pool instead of
//!   staying lost.
//!
//! Either way the worker executes **one attempt per `Task` frame** and
//! reports the raw result; retries, requeues, timeouts, and crash
//! accounting belong to the supervisor. A heartbeat thread shares the
//! write half of the connection so the supervisor can distinguish
//! "long-running task" from "hung worker".

use crate::coordinator::error::{panic_message, MementoError};
use crate::coordinator::task::{task_seed, ExpRef, TaskContext, TaskId};
use crate::experiments::registry::Registry;
use crate::ipc::proto::{
    read_frame, write_frame_as, Msg, WireFormat, WireResult, MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
};
use crate::ipc::transport::{Endpoint, WireStream};
use crate::obs::trace::monotonic_us;
use crate::util::json::Json;
use crate::util::time::Stopwatch;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Endpoint of the supervising process (a Unix socket path, or a
/// `tcp://host:port` address — see
/// [`Endpoint::parse`]); presence
/// of this variable is what makes a process a worker.
pub const ENV_SOCKET: &str = "MEMENTO_WORKER_SOCKET";
/// Slot id assigned by the supervisor (`0..workers`).
pub const ENV_WORKER_ID: &str = "MEMENTO_WORKER_ID";
/// Spawn generation within the slot; echoed back in the `Ready` handshake
/// so the supervisor can tell a fresh worker's connection from a stale
/// (already-replaced) incarnation's.
pub const ENV_WORKER_SPAWN: &str = "MEMENTO_WORKER_SPAWN";
/// Shared auth token presented in the `Ready` handshake (required by TCP
/// supervisors, unused over Unix sockets).
pub const ENV_WORKER_TOKEN: &str = "MEMENTO_WORKER_TOKEN";

/// True when this process was spawned as a worker by a supervisor.
pub fn active() -> bool {
    std::env::var_os(ENV_SOCKET).is_some()
}

/// If this process is a worker, serve tasks until shutdown and then
/// **exit the process**; otherwise return immediately. Call this early in
/// a binary that re-executes itself for process isolation.
pub fn maybe_serve(registry: Arc<Registry>) {
    if !active() {
        return;
    }
    match serve(registry) {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            eprintln!("memento worker: {e}");
            std::process::exit(70); // EX_SOFTWARE
        }
    }
}

/// Connects to the supervisor named by the environment and serves task
/// attempts until it sends `Shutdown` (or closes the connection). Returns
/// once the connection is drained; callers normally exit afterwards.
///
/// This is the **spawned-worker** entry: one connection, one run. For a
/// standing worker that outlives runs and reconnects, use
/// [`serve_remote`].
pub fn serve(registry: Arc<Registry>) -> Result<(), MementoError> {
    let endpoint_str = std::env::var(ENV_SOCKET)
        .map_err(|_| MementoError::ipc(format!("{ENV_SOCKET} not set")))?;
    let worker_id: u64 = std::env::var(ENV_WORKER_ID)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let spawn: u64 = std::env::var(ENV_WORKER_SPAWN)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let token = std::env::var(ENV_WORKER_TOKEN).ok();

    let endpoint = Endpoint::parse(&endpoint_str);
    let stream = endpoint
        .connect()
        .map_err(|e| MementoError::ipc(format!("connect {endpoint}: {e}")))?;
    // Spawned workers follow whatever format the supervisor negotiates in
    // its Hello — they are the same binary, so no cap is needed.
    let report =
        serve_connection(stream, &registry, worker_id, spawn, token, None, WireFormat::Binary)?;
    match report.end {
        ConnEnd::Shutdown | ConnEnd::TaskLimit => Ok(()),
        ConnEnd::PreHelloEof => Err(MementoError::ipc("supervisor closed before hello")),
        ConnEnd::Dropped(msg) => Err(MementoError::ipc(msg)),
    }
}

/// Tuning for a standing remote worker (see [`serve_remote`]).
#[derive(Debug, Clone)]
pub struct RemoteWorkerOptions {
    /// Shared auth token, presented in the `Ready` handshake. Required by
    /// any TCP supervisor pool.
    pub token: Option<String>,
    /// Self-reported worker id (diagnostics only — the pool assigns its
    /// own member ids).
    pub worker_id: u64,
    /// Stop after this many *served* connections (connections that
    /// reached `Hello`). `None` = serve forever; this is the standing
    /// `memento serve` default.
    pub max_connections: Option<usize>,
    /// Voluntarily close the connection (with a clean `Goodbye`) after
    /// this many task attempts, then reconnect and re-register. Useful
    /// for rolling restarts and for bounding per-connection state; `None`
    /// = never.
    pub tasks_per_connection: Option<usize>,
    /// Give up after the supervisor has been unreachable for this long
    /// (measured per outage, from the first failed connect). `None` =
    /// retry forever.
    pub give_up_after: Option<Duration>,
    /// First reconnect delay of an outage; doubles per retry.
    pub initial_backoff: Duration,
    /// Reconnect delay ceiling.
    pub max_backoff: Duration,
    /// Suppress per-connection log lines on stderr.
    pub quiet: bool,
    /// Ceiling on this worker's payload encoding. [`WireFormat::Json`]
    /// forces JSON frames even toward a v3 supervisor — the debugging
    /// mode behind `memento serve --wire json`. Readers auto-detect, so
    /// this never breaks interop; it only trades compactness for
    /// `tcpdump`-readability.
    pub wire: WireFormat,
}

impl Default for RemoteWorkerOptions {
    fn default() -> Self {
        RemoteWorkerOptions {
            token: None,
            worker_id: 0,
            max_connections: None,
            tasks_per_connection: None,
            give_up_after: None,
            initial_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            quiet: false,
            wire: WireFormat::Binary,
        }
    }
}

/// What a [`serve_remote`] session accomplished before returning.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteServeReport {
    /// Connections that reached `Hello` (≈ runs or run-shares served).
    pub connections: usize,
    /// Task attempts executed across all connections.
    pub tasks: usize,
}

/// Runs a **standing remote worker**: connect to `endpoint`, register
/// with the shared token, serve task attempts, and when the run ends
/// (`Shutdown`) or the connection drops, reconnect and re-register for
/// the next one — with exponential backoff while the supervisor is
/// unreachable, so a worker that drops mid-run rejoins the pool instead
/// of burning the run's failure budget.
///
/// Returns `Ok` when a configured bound is reached
/// ([`RemoteWorkerOptions::max_connections`] /
/// [`RemoteWorkerOptions::give_up_after`]); returns `Err` only on fatal
/// refusals (bad auth token, protocol mismatch) that a retry cannot fix.
/// This is the body of `memento serve`, and is equally callable on a
/// plain thread — tests and `examples/remote_workers.rs` run "remote"
/// workers in-process over loopback TCP this way.
pub fn serve_remote(
    registry: Arc<Registry>,
    endpoint: &Endpoint,
    opts: RemoteWorkerOptions,
) -> Result<RemoteServeReport, MementoError> {
    let mut report = RemoteServeReport::default();
    let mut backoff = opts.initial_backoff.max(Duration::from_millis(1));
    let mut outage_start: Option<Instant> = None;
    let mut spawn_gen: u64 = 0;

    loop {
        if let Some(max) = opts.max_connections {
            if report.connections >= max {
                return Ok(report);
            }
        }
        // One backoff step: give up if the outage outlasted the budget.
        let wait_or_give_up = |backoff: &mut Duration,
                               outage_start: &mut Option<Instant>|
         -> bool {
            let started = *outage_start.get_or_insert_with(Instant::now);
            if let Some(limit) = opts.give_up_after {
                if started.elapsed() >= limit {
                    return false;
                }
            }
            std::thread::sleep(*backoff);
            *backoff = (*backoff * 2).min(opts.max_backoff);
            true
        };

        let stream = match endpoint.connect() {
            Ok(s) => s,
            Err(e) => {
                if !opts.quiet && outage_start.is_none() {
                    eprintln!(
                        "memento worker: cannot reach {endpoint} ({e}); retrying with backoff"
                    );
                }
                if wait_or_give_up(&mut backoff, &mut outage_start) {
                    continue;
                }
                return Ok(report);
            }
        };
        spawn_gen += 1;
        let conn = serve_connection(
            stream,
            &registry,
            opts.worker_id,
            spawn_gen,
            opts.token.clone(),
            opts.tasks_per_connection,
            opts.wire,
        )?; // Err = fatal refusal (Reject / protocol mismatch): do not retry
        report.tasks += conn.tasks;
        match conn.end {
            // The pool accepted us but closed before handing out a run
            // (e.g. the supervisor shut down while we sat in the queue).
            // That is an outage, not a served connection.
            ConnEnd::PreHelloEof => {
                if wait_or_give_up(&mut backoff, &mut outage_start) {
                    continue;
                }
                return Ok(report);
            }
            ConnEnd::Shutdown | ConnEnd::TaskLimit => {
                report.connections += 1;
                outage_start = None;
                backoff = opts.initial_backoff.max(Duration::from_millis(1));
                if !opts.quiet {
                    eprintln!(
                        "memento worker: connection {} done ({} task(s) so far); re-registering",
                        report.connections, report.tasks
                    );
                }
            }
            // Mid-run drop (supervisor died, network blip): reconnect.
            ConnEnd::Dropped(msg) => {
                report.connections += 1;
                if !opts.quiet {
                    eprintln!("memento worker: connection dropped ({msg}); re-registering");
                }
                outage_start = None;
                backoff = opts.initial_backoff.max(Duration::from_millis(1));
            }
        }
    }
}

/// How one served connection ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConnEnd {
    /// The supervisor sent `Shutdown` (or closed cleanly between tasks).
    Shutdown,
    /// The worker left voluntarily after its per-connection task budget,
    /// announcing the departure with a `Goodbye` frame.
    TaskLimit,
    /// The connection closed before `Hello` ever arrived (the pool shut
    /// down while this worker waited in the registration queue).
    PreHelloEof,
    /// The connection failed mid-run (I/O error or a desynced frame); the
    /// message describes how.
    Dropped(String),
}

/// Outcome of serving one connection (see [`serve_connection`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConnReport {
    /// Task attempts executed on this connection.
    pub tasks: usize,
    /// How the connection ended.
    pub end: ConnEnd,
}

/// Serves one established connection: `Ready` handshake, `Hello` (or
/// `Reject`), then task attempts until `Shutdown`, EOF, or the optional
/// `tasks_limit` (announced with `Goodbye`). The shared core of both
/// [`serve`] and [`serve_remote`].
///
/// `Err` is reserved for **fatal refusals** — an explicit `Reject` or a
/// protocol-version mismatch — that reconnecting cannot fix; transport
/// failures come back as `Ok` with [`ConnEnd::Dropped`] so standing
/// workers can retry.
///
/// `wire_cap` bounds this worker's payload encoding: the connection
/// speaks binary only when the supervisor is v3+, its `Hello` asked for
/// binary, **and** the cap allows it — otherwise every frame this side
/// writes is JSON (which any peer can read).
pub fn serve_connection(
    stream: Box<dyn WireStream>,
    registry: &Arc<Registry>,
    worker_id: u64,
    spawn: u64,
    token: Option<String>,
    tasks_limit: Option<usize>,
    wire_cap: WireFormat,
) -> Result<ConnReport, MementoError> {
    let mut reader = stream;
    let writer: Arc<Mutex<Box<dyn WireStream>>> = Arc::new(Mutex::new(
        reader
            .try_clone_stream()
            .map_err(|e| MementoError::ipc(format!("clone stream: {e}")))?,
    ));

    // Handshake frames are pinned to JSON by write_frame_as regardless of
    // the format passed here.
    send(
        &writer,
        &Msg::Ready {
            worker: worker_id,
            pid: std::process::id() as u64,
            spawn,
            protocol: PROTOCOL_VERSION,
            token,
            // Monotonic clock sample for the supervisor's per-worker
            // offset estimate; worker-side exec timestamps in later
            // Outcome frames are on this same clock.
            clock_us: Some(monotonic_us()),
            // Capability advertisement: the named experiments this
            // registry serves. An empty list is meaningful — it says
            // "unnamed tasks only", unlike a pre-v5 peer's absent field
            // which the supervisor must *assume* means the same.
            exps: Some(registry.names()),
        },
        WireFormat::Json,
    )?;

    // First frame must be the run configuration (or a refusal).
    let hello = match read_frame(&mut reader) {
        Ok(Some(m)) => m,
        Ok(None) => return Ok(ConnReport { tasks: 0, end: ConnEnd::PreHelloEof }),
        Err(e) => {
            return Ok(ConnReport {
                tasks: 0,
                end: ConnEnd::Dropped(format!("read hello: {e}")),
            })
        }
    };
    let (protocol, version, run_seed, settings, heartbeat_ms, hello_wire) = match hello {
        Msg::Hello { protocol, version, run_seed, settings, heartbeat_ms, wire } => {
            (protocol, version, run_seed, settings, heartbeat_ms, wire)
        }
        Msg::Reject { reason } => {
            return Err(MementoError::ipc(format!(
                "supervisor rejected this worker: {reason}"
            )))
        }
        other => {
            return Err(MementoError::ipc(format!(
                "expected hello as first frame, got {other:?}"
            )))
        }
    };
    if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&protocol) {
        return Err(MementoError::ipc(format!(
            "protocol mismatch: supervisor speaks v{protocol}, worker speaks \
             v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION}"
        )));
    }
    // Negotiated payload format for everything this side writes from here
    // on: binary only when the supervisor can parse it (v3+), asked for
    // it, and our own cap allows it. A v2 supervisor never sees binary.
    let wire = if protocol >= 3 && hello_wire == WireFormat::Binary {
        wire_cap
    } else {
        WireFormat::Json
    };
    let settings = Arc::new(settings);

    // Heartbeat thread: shares the writer; `busy` mirrors the task index
    // currently executing (-1 = idle) so the supervisor can tell a slow
    // task from a wedged worker. Heartbeats flow **only while busy**: the
    // supervisor reads the stream only while an attempt is in flight, so
    // idle heartbeats would accumulate unread in the socket buffer — and
    // a filled buffer would block this thread inside `write` holding the
    // writer lock, wedging the worker (and, for spawned workers, the
    // supervisor's final `child.wait()`) forever. Idle liveness needs no
    // signal: a dead idle worker is detected by the next task dispatch
    // failing.
    let busy = Arc::new(AtomicI64::new(-1));
    let stop = Arc::new(AtomicBool::new(false));
    let hb_handle = spawn_heartbeat(
        Arc::clone(&writer),
        worker_id,
        Arc::clone(&busy),
        Arc::clone(&stop),
        Duration::from_millis(heartbeat_ms.max(1)),
        wire,
    );

    let report = serve_loop(
        &mut *reader,
        &writer,
        registry,
        &settings,
        &version,
        run_seed,
        &busy,
        tasks_limit,
        wire,
        protocol,
    );

    stop.store(true, Ordering::SeqCst);
    let _ = hb_handle.join();

    if matches!(report.end, ConnEnd::TaskLimit) {
        // A dispatch may have crossed with our Goodbye and be sitting
        // unread in the receive buffer. Closing now would make TCP answer
        // the supervisor with an RST, which on common stacks *discards
        // the supervisor's buffered-but-unread data — the Goodbye
        // itself* — turning this clean departure into a crash charge.
        // So the connection is never closed from this side: a detached
        // thread drains it until the supervisor (having read the
        // Goodbye) closes, consuming any crossed frame along the way.
        // The worker's reconnect proceeds immediately in parallel. The
        // generous read deadline is only a leak backstop for a wedged
        // supervisor.
        let _ = reader.set_stream_read_timeout(Some(Duration::from_secs(60)));
        let _ = std::thread::Builder::new()
            .name("memento-goodbye-drain".into())
            .spawn(move || {
                let mut reader = reader;
                while let Ok(Some(_)) = read_frame(&mut reader) {}
            });
    }
    Ok(report)
}

#[allow(clippy::too_many_arguments)]
fn serve_loop(
    mut reader: &mut dyn WireStream,
    writer: &Arc<Mutex<Box<dyn WireStream>>>,
    registry: &Arc<Registry>,
    settings: &Arc<BTreeMap<String, Json>>,
    version: &str,
    run_seed: u64,
    busy: &Arc<AtomicI64>,
    tasks_limit: Option<usize>,
    wire: WireFormat,
    protocol: u64,
) -> ConnReport {
    let mut tasks = 0usize;
    loop {
        let msg = match read_frame(&mut reader) {
            Ok(m) => m,
            Err(e) => {
                return ConnReport {
                    tasks,
                    end: ConnEnd::Dropped(format!("read task: {e}")),
                }
            }
        };
        match msg {
            None | Some(Msg::Shutdown) => return ConnReport { tasks, end: ConnEnd::Shutdown },
            Some(Msg::Task { index, attempt, params, restored, exp, exp_version }) => {
                busy.store(index as i64, Ordering::SeqCst);
                let outcome = run_attempt(
                    writer, registry, settings, version, run_seed, index, attempt, params,
                    restored, exp, exp_version, wire, protocol,
                );
                busy.store(-1, Ordering::SeqCst);
                tasks += 1;
                if send(writer, &outcome, wire).is_err() {
                    return ConnReport {
                        tasks,
                        end: ConnEnd::Dropped("write outcome failed".to_string()),
                    };
                }
                if let Some(limit) = tasks_limit {
                    if tasks >= limit {
                        // Announce the voluntary departure so the
                        // supervisor re-queues any racing dispatch without
                        // charging a retry attempt or crash budget.
                        let _ = send(writer, &Msg::Goodbye, wire);
                        return ConnReport { tasks, end: ConnEnd::TaskLimit };
                    }
                }
            }
            Some(other) => {
                return ConnReport {
                    tasks,
                    end: ConnEnd::Dropped(format!(
                        "unexpected frame from supervisor: {other:?}"
                    )),
                }
            }
        }
    }
}

/// Executes one attempt and builds its `Outcome` frame. Panics in the
/// experiment function are contained here, exactly as the thread backend
/// contains them — only failures *of the process itself* reach the
/// supervisor as crashes.
#[allow(clippy::too_many_arguments)]
fn run_attempt(
    writer: &Arc<Mutex<Box<dyn WireStream>>>,
    registry: &Arc<Registry>,
    settings: &Arc<BTreeMap<String, Json>>,
    version: &str,
    run_seed: u64,
    index: u64,
    attempt: u64,
    params: Vec<(String, crate::config::value::ParamValue)>,
    restored: Option<Json>,
    exp: Option<String>,
    exp_version: Option<String>,
    wire: WireFormat,
    protocol: u64,
) -> Msg {
    let mut spec = Msg::task_spec(index, &params);
    // A named task hashes with the entry version the *supervisor*
    // registered (carried on the frame), not whatever version this
    // worker happens to register locally — both sides must derive the
    // same id or caching and progress relay fall apart.
    spec.exp = exp.map(|name| ExpRef {
        name,
        version: exp_version.unwrap_or_else(|| version.to_string()),
    });
    let id = spec.id(version);
    let seed = task_seed(run_seed, &id);
    let exp_fn = match registry.resolve(spec.exp.as_ref()) {
        Ok(f) => f,
        Err(e) => {
            // Capability mismatch: report it as such (v5+) so the
            // supervisor re-routes without charging this worker. A
            // pre-v5 supervisor never sends named tasks, but an unnamed
            // task can still miss a fallback-less registry — same shape.
            return Msg::Outcome {
                index,
                attempt,
                duration_secs: 0.0,
                exec_start_us: None,
                exec_end_us: None,
                result: WireResult::Unsupported { message: e.to_string() },
            };
        }
    };

    // Partial progress is relayed to the supervisor, which persists it in
    // the checkpoint store — the worker never touches the store directly.
    let w2 = Arc::clone(writer);
    let sink: Arc<dyn Fn(&TaskId, &Json) + Send + Sync> = Arc::new(move |_tid, value| {
        let _ = send(&w2, &Msg::Progress { index, value: value.clone() }, wire);
    });

    let ctx = TaskContext::new(
        spec,
        Arc::clone(settings),
        seed,
        attempt as u32,
        id,
        restored,
        Some(sink),
    );
    let exec_start = monotonic_us();
    let sw = Stopwatch::start();
    let result = match catch_unwind(AssertUnwindSafe(|| exp_fn(&ctx))) {
        Ok(Ok(value)) => WireResult::Ok { value },
        Ok(Err(e)) => WireResult::Err { message: e.to_string(), panicked: false },
        Err(payload) => WireResult::Err {
            message: panic_message(payload.as_ref()),
            panicked: true,
        },
    };
    let exec_end = monotonic_us();
    // Worker-clock exec timestamps are a v4 addition. Pre-v4 supervisors
    // tolerate unknown JSON keys but the fields are withheld anyway so the
    // frame matches what the negotiated protocol promises.
    let (exec_start_us, exec_end_us) = if protocol >= 4 {
        (Some(exec_start), Some(exec_end))
    } else {
        (None, None)
    };
    Msg::Outcome {
        index,
        attempt,
        duration_secs: sw.elapsed_secs(),
        exec_start_us,
        exec_end_us,
        result,
    }
}

fn send(
    writer: &Arc<Mutex<Box<dyn WireStream>>>,
    msg: &Msg,
    wire: WireFormat,
) -> Result<(), MementoError> {
    let mut w = writer.lock().unwrap();
    write_frame_as(&mut *w, msg, wire).map_err(|e| MementoError::ipc(format!("write frame: {e}")))
}

fn spawn_heartbeat(
    writer: Arc<Mutex<Box<dyn WireStream>>>,
    worker: u64,
    busy: Arc<AtomicI64>,
    stop: Arc<AtomicBool>,
    interval: Duration,
    wire: WireFormat,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("memento-ipc-heartbeat".into())
        .spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(interval);
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                let b = busy.load(Ordering::SeqCst);
                if b < 0 {
                    continue; // idle: nobody is reading, don't fill the pipe
                }
                let msg = Msg::Heartbeat { worker, busy: Some(b as u64) };
                if send(&writer, &msg, wire).is_err() {
                    // Supervisor is gone; the serve loop will notice on its
                    // next read. Nothing useful left to do here.
                    return;
                }
            }
        })
        .expect("spawn heartbeat thread")
}
