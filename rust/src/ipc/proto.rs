//! The supervisor ↔ worker wire protocol.
//!
//! # Framing
//!
//! Every message is one **length-prefixed frame**: a 4-byte big-endian
//! payload length followed by the payload bytes. Since v3 the payload is
//! self-describing: a leading [`crate::util::codec::BINARY_MAGIC`] byte
//! marks the compact tagged binary encoding (the default), anything else
//! is compact JSON text (serialized via [`crate::util::json`]) — the
//! debugging fallback and the only format pre-v3 peers speak. Readers
//! auto-detect per payload ([`read_frame`]), so a connection may carry
//! both formats. The **handshake frames** (`Ready`, `Hello`, `Reject`)
//! are always written as JSON regardless of the negotiated format, which
//! is what lets a v2 peer parse the negotiation itself and keep working.
//! Frames are small (a task assignment or an outcome); a hard
//! [`MAX_FRAME`] cap turns a corrupted length prefix into a clean
//! protocol error instead of an attempted multi-GiB allocation.
//!
//! # Message flow
//!
//! ```text
//! worker                                    supervisor
//!   | -- Ready{worker,pid,protocol,token} --> |   (handshake: routes spawned
//!   | <------- Hello{version,seed,...} ------ |    workers to their slot;
//!   |     (or Reject{reason} + close)         |    registers TCP workers
//!   | <------- Task{index,attempt,...} ------ |    after token/version check)
//!   | -- Progress{index,value} -------------> |   (0..n per task)
//!   | -- Heartbeat{busy} -------------------> |   (every heartbeat interval)
//!   | -- Outcome{index,attempt,result} -----> |
//!   | <------- Task | Shutdown -------------- |
//!   | -- Goodbye ---------------------------> |   (clean worker departure)
//! ```
//!
//! The same frames flow over every transport (Unix socket or TCP — see
//! [`crate::ipc::transport`]); only the trust model differs. Over TCP the
//! `Ready` frame must carry the shared token and a matching protocol
//! version, or the supervisor answers `Reject` and drops the connection.
//!
//! One `Task` frame is **one attempt**: the supervisor owns the retry
//! policy (it must — a worker that dies mid-attempt cannot retry itself),
//! so the worker executes exactly one attempt per assignment and reports
//! the raw result. Parameters travel as an *array* of `[name, value]`
//! pairs, not an object, so the matrix's declaration order survives the
//! trip (task ids hash a sorted canonical form and are order-independent,
//! but labels and reports are not).
//!
//! # Daemon flow (v6)
//!
//! The same framing carries the client ↔ daemon submission protocol (see
//! [`crate::daemon`]): a client opens with `Submit` or `Attach` (both
//! JSON-pinned handshakes carrying the token), the daemon answers
//! `Accepted{run_id}` or `Reject{reason}`, then streams `Event` frames
//! until the run completes or the client sends `Detach`. `serve` workers
//! never see these frames — the daemon speaks plain v5 toward its pool.

use crate::config::value::ParamValue;
use crate::coordinator::task::TaskSpec;
use crate::util::codec;
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::io::{self, Read, Write};

/// Bumped on any incompatible change; the worker refuses a supervisor it
/// cannot understand rather than misinterpreting frames, and the
/// accepting side ([`crate::ipc::pool::WorkerPool`]) rejects an
/// incompatible worker at registration. v2 added the
/// distributed-execution handshake: `Ready` carries the speaker's
/// protocol version and (for TCP peers) the shared auth token, plus the
/// `Goodbye`/`Reject` lifecycle frames. v3 added binary payloads: frames
/// default to the tagged binary encoding, negotiated at `Ready`/`Hello`,
/// with handshake frames pinned to JSON — so v3 speakers interoperate
/// with v2 peers (both sides fall back to all-JSON) and v2/v3 are
/// mutually compatible rather than rejected. v4 added observability
/// fields, all optional: `Ready` carries the worker's monotonic clock
/// reading (`clock_us`, for per-worker clock-offset estimation) and
/// `Outcome` carries worker-side `exec_start_us`/`exec_end_us`
/// timestamps so merged span timelines cross process and machine
/// boundaries. Pre-v4 readers ignore unknown JSON keys and the binary
/// codec is self-describing, so v2/v3 peers interoperate unchanged —
/// the supervisor synthesizes exec timestamps from `duration_secs`
/// when a peer omits them. v5 added the experiment-registry fields,
/// all optional: `Ready` carries the experiment names the worker can
/// serve (`exps`), `Task` names the experiment it targets
/// (`exp`/`exp_version`), and `Outcome` gained the `unsupported`
/// result shape for a name the worker does not register. A pre-v5
/// peer emits and parses none of these — the supervisor treats such a
/// worker as capable only of *unnamed* (single-experiment) tasks and
/// never routes named work to it, so v2–v4 peers interoperate
/// unchanged. v6 added the daemon submission frames — `Submit`,
/// `Accepted`, `Event`, `Attach`, `Detach` — spoken only on client ↔
/// daemon connections; the worker-facing frames are untouched, so every
/// v2–v5 `serve` worker registers and executes exactly as before. Only
/// a pre-v6 peer attempting `Submit`/`Attach` against a daemon is
/// rejected (with a version message), because those frames did not
/// exist before v6.
pub const PROTOCOL_VERSION: u64 = 6;

/// Oldest protocol version current code interoperates with. v2 peers
/// lack binary payload support but are frame-compatible otherwise, so
/// accepting sides admit `MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION` and
/// simply speak JSON to the older end.
pub const MIN_PROTOCOL_VERSION: u64 = 2;

/// Upper bound on a single frame's payload (64 MiB). Experiment results
/// are JSON metric objects; anything larger indicates a corrupted stream.
pub const MAX_FRAME: usize = 64 << 20;

pub use crate::util::codec::WireFormat;

/// Result of one task attempt, as reported by a worker.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResult {
    /// The experiment function returned a value.
    Ok {
        /// The returned metrics object.
        value: Json,
    },
    /// The attempt failed; `panicked` distinguishes a contained panic
    /// from an `Err` return.
    Err {
        /// Human-readable error/panic message.
        message: String,
        /// True when the failure was a contained panic.
        panicked: bool,
    },
    /// The worker does not register the experiment the task names
    /// (v5+). A capability mismatch is a *dispatch* problem, not a
    /// worker fault: the supervisor re-routes the attempt to a capable
    /// worker without charging this worker's crash budget.
    Unsupported {
        /// Human-readable reason naming the missing experiment.
        message: String,
    },
}

/// One protocol message (either direction).
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    // ---- worker → supervisor -------------------------------------------
    /// Handshake: first frame on a fresh connection. `spawn` echoes the
    /// supervisor-assigned spawn generation so a connection from a stale
    /// (crashed and replaced) incarnation of a slot can never be mistaken
    /// for the replacement worker. `protocol` declares the worker's wire
    /// version and `token` carries the shared secret — TCP-registered
    /// workers are untrusted, so the accepting side verifies both before
    /// the connection is allowed anywhere near a run (a mismatch is
    /// answered with [`Msg::Reject`] and a closed connection).
    Ready {
        /// Slot id (spawned workers) or self-chosen id (remote workers).
        worker: u64,
        /// The worker's OS process id, for log attribution.
        pid: u64,
        /// Spawn generation within the slot (spawned workers; 0 otherwise).
        spawn: u64,
        /// The worker's [`PROTOCOL_VERSION`].
        protocol: u64,
        /// Shared auth token; required by TCP pools, unused over Unix
        /// sockets (filesystem permissions are the trust boundary there).
        token: Option<String>,
        /// The worker's monotonic clock at send time, in microseconds
        /// (v4+). The accepting side subtracts it from its own clock at
        /// receipt to estimate this worker's clock offset (error bounded
        /// by the connection's one-way latency), which is how worker-side
        /// exec timestamps land on the coordinator's timeline. `None`
        /// from pre-v4 peers.
        clock_us: Option<u64>,
        /// The experiment names this worker's registry can serve (v5+).
        /// `None` from pre-v5 peers, which the accepting side treats as
        /// "unnamed tasks only" — it never routes a named task there.
        exps: Option<Vec<String>>,
    },
    /// Clean departure: the worker is about to close this connection
    /// deliberately (rolling restart, per-connection task budget) and
    /// guarantees it will execute nothing sent after this frame. The
    /// supervisor re-queues any dispatch that crossed with it **without**
    /// consuming a retry attempt or crash budget.
    Goodbye,
    /// Liveness signal; `busy` names the task index being executed, if any.
    Heartbeat {
        /// The sending worker's id.
        worker: u64,
        /// Wire index of the task currently executing (`None` = idle).
        busy: Option<u64>,
    },
    /// In-task partial progress (`TaskContext::save_progress` relay).
    Progress {
        /// Wire index of the task reporting progress.
        index: u64,
        /// The saved progress payload.
        value: Json,
    },
    /// Terminal report for one attempt.
    Outcome {
        /// Wire index of the finished task.
        index: u64,
        /// The attempt number this outcome answers.
        attempt: u64,
        /// Wall-clock execution time inside the worker.
        duration_secs: f64,
        /// When the experiment function started, on the *worker's*
        /// monotonic clock in microseconds (v4+; `None` from older
        /// peers, or when the negotiated protocol is below 4). The
        /// supervisor maps it onto its own timeline via the clock
        /// offset estimated at `Ready`.
        exec_start_us: Option<u64>,
        /// When the experiment function returned, worker clock (v4+).
        exec_end_us: Option<u64>,
        /// The attempt's result.
        result: WireResult,
    },

    // ---- supervisor → worker -------------------------------------------
    /// Run-wide configuration; first frame after `Ready`.
    Hello {
        /// The supervisor's [`PROTOCOL_VERSION`].
        protocol: u64,
        /// Experiment version salt (task hashing must match).
        version: String,
        /// Base RNG seed; per-task seeds derive from it and the task id.
        run_seed: u64,
        /// The matrix's run-wide settings.
        settings: BTreeMap<String, Json>,
        /// Heartbeat interval the worker must observe, in milliseconds.
        heartbeat_ms: u64,
        /// The payload format the supervisor will use for its
        /// post-handshake frames — and an invitation for the worker to
        /// answer in kind when both ends are v3+. Absent in v2 Hellos
        /// (parsed as [`WireFormat::Binary`], which is harmless: the
        /// worker only switches to binary when `protocol >= 3` too).
        wire: WireFormat,
    },
    /// One attempt assignment.
    Task {
        /// Wire handle for this task (the supervisor's pulled-task index).
        index: u64,
        /// 1-based attempt number.
        attempt: u64,
        /// Parameter assignment, in matrix declaration order.
        params: Vec<(String, ParamValue)>,
        /// Progress restored from a previous attempt, if any.
        restored: Option<Json>,
        /// Name of the registered experiment this task targets (v5+).
        /// `None` means the unnamed single-experiment workload — the
        /// only shape a pre-v5 worker can be sent.
        exp: Option<String>,
        /// The named experiment's registered version — the id-hash salt
        /// the worker must use for a named task (v5+; `None` iff `exp`
        /// is `None`, in which case the run-wide version salts the id).
        exp_version: Option<String>,
    },
    /// Orderly termination; the worker drains and exits (standing remote
    /// workers treat this as end-of-run and reconnect for the next one).
    Shutdown,
    /// Registration refused (bad auth token, protocol mismatch). Terminal:
    /// the connection is closed right after, and the worker must not
    /// retry with the same credentials.
    Reject {
        /// Human-readable refusal reason, surfaced in the worker's error.
        reason: String,
    },

    // ---- client → daemon (v6) ------------------------------------------
    /// Run submission: first frame on a client → daemon connection.
    /// Token-authenticated exactly like pool registration — the daemon
    /// verifies `protocol` and `token` before revealing any state, and
    /// answers [`Msg::Accepted`] or [`Msg::Reject`]. JSON-pinned (it is
    /// a handshake frame): the daemon has negotiated nothing yet.
    Submit {
        /// The client's [`PROTOCOL_VERSION`]; must be v6+.
        protocol: u64,
        /// Shared auth token; required by TCP daemons, unused over Unix
        /// sockets (filesystem permissions are the trust boundary there).
        token: Option<String>,
        /// Tenant name the run is accounted under (quota + store label).
        tenant: String,
        /// The serialized [`crate::config::matrix::ConfigMatrix`]
        /// (`ConfigMatrix::to_json` shape, reparsed by the daemon).
        matrix: Json,
        /// Registered experiment to resolve against the daemon's builtin
        /// registry (`None` = the daemon's fallback experiment).
        exp: Option<String>,
        /// Experiment version salt for task ids (`None` = daemon default).
        version: Option<String>,
        /// Base RNG seed for the run (string-encoded, like `run_seed`).
        seed: u64,
        /// Optional human-readable run label suffix.
        label: Option<String>,
    },
    /// Resume streaming an accepted run's events: first frame on a
    /// client → daemon connection, authenticated like [`Msg::Submit`].
    /// The empty `run_id` addresses the daemon itself — the daemon
    /// answers one [`Msg::Event`] carrying its status document (and the
    /// connection may then send [`Msg::Shutdown`] to request a drain).
    Attach {
        /// The client's [`PROTOCOL_VERSION`]; must be v6+.
        protocol: u64,
        /// Shared auth token (same rule as [`Msg::Submit`]).
        token: Option<String>,
        /// The run to attach to, or `""` for the daemon status channel.
        run_id: String,
    },
    /// Stop streaming events to this client without cancelling the run;
    /// the daemon keeps draining into the shared store and a later
    /// [`Msg::Attach`] replays the terminal events.
    Detach,

    // ---- daemon → client (v6) ------------------------------------------
    /// Submission admitted: the run is queued (or already executing)
    /// under `run_id`, the handle for [`Msg::Attach`] and the store's
    /// per-tenant run label.
    Accepted {
        /// Daemon-assigned run id (`tenant/...`-prefixed store label).
        run_id: String,
    },
    /// One run event, streamed to every attached client. The payload is
    /// the [`crate::coordinator::run::RunEvent`] wire JSON (the same
    /// shape `--output ndjson` prints).
    Event {
        /// The run this event belongs to (`""` = daemon status answer).
        run_id: String,
        /// The event document.
        event: Json,
    },
}

impl Msg {
    /// Serializes the message to its wire JSON shape.
    pub fn to_json(&self) -> Json {
        match self {
            Msg::Ready { worker, pid, spawn, protocol, token, clock_us, exps } => {
                let mut fields = vec![
                    ("msg", Json::str("ready")),
                    ("worker", Json::int(*worker as i64)),
                    ("pid", Json::int(*pid as i64)),
                    ("spawn", Json::int(*spawn as i64)),
                    ("protocol", Json::int(*protocol as i64)),
                    (
                        "token",
                        token
                            .as_ref()
                            .map(|t| Json::str(t.clone()))
                            .unwrap_or(Json::Null),
                    ),
                ];
                if let Some(clock) = clock_us {
                    fields.push(("clock_us", Json::int(*clock as i64)));
                }
                if let Some(names) = exps {
                    fields.push((
                        "exps",
                        Json::Arr(names.iter().map(|n| Json::str(n.clone())).collect()),
                    ));
                }
                Json::obj(fields)
            }
            Msg::Goodbye => Json::obj(vec![("msg", Json::str("goodbye"))]),
            Msg::Reject { reason } => Json::obj(vec![
                ("msg", Json::str("reject")),
                ("reason", Json::str(reason.clone())),
            ]),
            Msg::Heartbeat { worker, busy } => Json::obj(vec![
                ("msg", Json::str("heartbeat")),
                ("worker", Json::int(*worker as i64)),
                (
                    "busy",
                    busy.map(|b| Json::int(b as i64)).unwrap_or(Json::Null),
                ),
            ]),
            Msg::Progress { index, value } => Json::obj(vec![
                ("msg", Json::str("progress")),
                ("index", Json::int(*index as i64)),
                ("value", value.clone()),
            ]),
            Msg::Outcome { index, attempt, duration_secs, exec_start_us, exec_end_us, result } => {
                let mut fields = vec![
                    ("msg", Json::str("outcome")),
                    ("index", Json::int(*index as i64)),
                    ("attempt", Json::int(*attempt as i64)),
                    ("duration_secs", Json::Num(*duration_secs)),
                ];
                if let Some(start) = exec_start_us {
                    fields.push(("exec_start_us", Json::int(*start as i64)));
                }
                if let Some(end) = exec_end_us {
                    fields.push(("exec_end_us", Json::int(*end as i64)));
                }
                match result {
                    WireResult::Ok { value } => {
                        fields.push(("ok", Json::bool(true)));
                        fields.push(("value", value.clone()));
                    }
                    WireResult::Err { message, panicked } => {
                        fields.push(("ok", Json::bool(false)));
                        fields.push(("message", Json::str(message.clone())));
                        fields.push(("panicked", Json::bool(*panicked)));
                    }
                    WireResult::Unsupported { message } => {
                        fields.push(("ok", Json::bool(false)));
                        fields.push(("unsupported", Json::bool(true)));
                        fields.push(("message", Json::str(message.clone())));
                    }
                }
                Json::obj(fields)
            }
            Msg::Hello { protocol, version, run_seed, settings, heartbeat_ms, wire } => {
                Json::obj(vec![
                    ("msg", Json::str("hello")),
                    ("protocol", Json::int(*protocol as i64)),
                    ("version", Json::str(version.clone())),
                    ("run_seed", Json::str(run_seed.to_string())), // u64 > 2^53-safe
                    ("settings", Json::Obj(settings.clone())),
                    ("heartbeat_ms", Json::int(*heartbeat_ms as i64)),
                    ("wire", Json::str(wire.as_str())),
                ])
            }
            Msg::Task { index, attempt, params, restored, exp, exp_version } => {
                let mut fields = vec![
                    ("msg", Json::str("task")),
                    ("index", Json::int(*index as i64)),
                    ("attempt", Json::int(*attempt as i64)),
                    (
                        "params",
                        Json::Arr(
                            params
                                .iter()
                                .map(|(k, v)| Json::Arr(vec![Json::str(k.clone()), v.to_json()]))
                                .collect(),
                        ),
                    ),
                    ("restored", restored.clone().unwrap_or(Json::Null)),
                ];
                if let Some(name) = exp {
                    fields.push(("exp", Json::str(name.clone())));
                }
                if let Some(ver) = exp_version {
                    fields.push(("exp_version", Json::str(ver.clone())));
                }
                Json::obj(fields)
            }
            Msg::Shutdown => Json::obj(vec![("msg", Json::str("shutdown"))]),
            Msg::Submit { protocol, token, tenant, matrix, exp, version, seed, label } => {
                let mut fields = vec![
                    ("msg", Json::str("submit")),
                    ("protocol", Json::int(*protocol as i64)),
                    (
                        "token",
                        token
                            .as_ref()
                            .map(|t| Json::str(t.clone()))
                            .unwrap_or(Json::Null),
                    ),
                    ("tenant", Json::str(tenant.clone())),
                    ("matrix", matrix.clone()),
                    ("seed", Json::str(seed.to_string())), // u64 > 2^53-safe
                ];
                if let Some(name) = exp {
                    fields.push(("exp", Json::str(name.clone())));
                }
                if let Some(ver) = version {
                    fields.push(("version", Json::str(ver.clone())));
                }
                if let Some(l) = label {
                    fields.push(("label", Json::str(l.clone())));
                }
                Json::obj(fields)
            }
            Msg::Attach { protocol, token, run_id } => Json::obj(vec![
                ("msg", Json::str("attach")),
                ("protocol", Json::int(*protocol as i64)),
                (
                    "token",
                    token
                        .as_ref()
                        .map(|t| Json::str(t.clone()))
                        .unwrap_or(Json::Null),
                ),
                ("run_id", Json::str(run_id.clone())),
            ]),
            Msg::Detach => Json::obj(vec![("msg", Json::str("detach"))]),
            Msg::Accepted { run_id } => Json::obj(vec![
                ("msg", Json::str("accepted")),
                ("run_id", Json::str(run_id.clone())),
            ]),
            Msg::Event { run_id, event } => Json::obj(vec![
                ("msg", Json::str("event")),
                ("run_id", Json::str(run_id.clone())),
                ("event", event.clone()),
            ]),
        }
    }

    /// Parses a wire JSON document back into a message; `None` for
    /// unknown or malformed shapes.
    pub fn from_json(j: &Json) -> Option<Msg> {
        let u64_field = |name: &str| j.get(name).and_then(|v| v.as_i64()).map(|v| v as u64);
        match j.get("msg")?.as_str()? {
            "ready" => Some(Msg::Ready {
                worker: u64_field("worker")?,
                pid: u64_field("pid")?,
                spawn: u64_field("spawn").unwrap_or(0),
                // Absent on pre-v2 peers: 0 never matches PROTOCOL_VERSION,
                // so an accepting pool rejects them with a clear reason.
                protocol: u64_field("protocol").unwrap_or(0),
                token: j
                    .get("token")
                    .and_then(|t| t.as_str())
                    .map(|t| t.to_string()),
                clock_us: u64_field("clock_us"),
                // Absent on pre-v5 peers; non-string entries are dropped
                // rather than failing the whole handshake frame.
                exps: j.get("exps").and_then(|e| e.as_arr()).map(|arr| {
                    arr.iter()
                        .filter_map(|n| n.as_str())
                        .map(|n| n.to_string())
                        .collect()
                }),
            }),
            "goodbye" => Some(Msg::Goodbye),
            "reject" => Some(Msg::Reject {
                reason: j
                    .get("reason")
                    .and_then(|r| r.as_str())
                    .unwrap_or("unspecified")
                    .to_string(),
            }),
            "heartbeat" => Some(Msg::Heartbeat {
                worker: u64_field("worker")?,
                busy: j.get("busy").and_then(|b| b.as_i64()).map(|b| b as u64),
            }),
            "progress" => Some(Msg::Progress {
                index: u64_field("index")?,
                value: j.get("value")?.clone(),
            }),
            "outcome" => {
                let result = if j.get("ok")?.as_bool()? {
                    WireResult::Ok { value: j.get("value")?.clone() }
                } else if j
                    .get("unsupported")
                    .and_then(|u| u.as_bool())
                    .unwrap_or(false)
                {
                    WireResult::Unsupported {
                        message: j.get("message")?.as_str()?.to_string(),
                    }
                } else {
                    WireResult::Err {
                        message: j.get("message")?.as_str()?.to_string(),
                        panicked: j.get("panicked").and_then(|p| p.as_bool()).unwrap_or(false),
                    }
                };
                Some(Msg::Outcome {
                    index: u64_field("index")?,
                    attempt: u64_field("attempt")?,
                    duration_secs: j.get("duration_secs")?.as_f64()?,
                    exec_start_us: u64_field("exec_start_us"),
                    exec_end_us: u64_field("exec_end_us"),
                    result,
                })
            }
            "hello" => Some(Msg::Hello {
                protocol: u64_field("protocol")?,
                version: j.get("version")?.as_str()?.to_string(),
                run_seed: j.get("run_seed")?.as_str()?.parse().ok()?,
                settings: j.get("settings")?.as_obj()?.clone(),
                heartbeat_ms: u64_field("heartbeat_ms")?,
                // Absent on v2 supervisors; Binary is safe because the
                // format switch additionally requires protocol >= 3.
                wire: j
                    .get("wire")
                    .and_then(|w| w.as_str())
                    .and_then(WireFormat::parse_arg)
                    .unwrap_or_default(),
            }),
            "task" => {
                let mut params = Vec::new();
                for pair in j.get("params")?.as_arr()? {
                    let name = pair.at(0)?.as_str()?.to_string();
                    let value = ParamValue::from_json(pair.at(1)?)?;
                    params.push((name, value));
                }
                let restored = match j.get("restored") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.clone()),
                };
                Some(Msg::Task {
                    index: u64_field("index")?,
                    attempt: u64_field("attempt")?,
                    params,
                    restored,
                    exp: j.get("exp").and_then(|e| e.as_str()).map(|e| e.to_string()),
                    exp_version: j
                        .get("exp_version")
                        .and_then(|v| v.as_str())
                        .map(|v| v.to_string()),
                })
            }
            "shutdown" => Some(Msg::Shutdown),
            "submit" => Some(Msg::Submit {
                // Absent protocol parses as 0, which a daemon then
                // rejects with a version message rather than a parse
                // error — same convention as pre-v2 Ready frames.
                protocol: u64_field("protocol").unwrap_or(0),
                token: j
                    .get("token")
                    .and_then(|t| t.as_str())
                    .map(|t| t.to_string()),
                tenant: j.get("tenant")?.as_str()?.to_string(),
                matrix: j.get("matrix")?.clone(),
                exp: j.get("exp").and_then(|e| e.as_str()).map(|e| e.to_string()),
                version: j
                    .get("version")
                    .and_then(|v| v.as_str())
                    .map(|v| v.to_string()),
                seed: j.get("seed")?.as_str()?.parse().ok()?,
                label: j
                    .get("label")
                    .and_then(|l| l.as_str())
                    .map(|l| l.to_string()),
            }),
            "attach" => Some(Msg::Attach {
                protocol: u64_field("protocol").unwrap_or(0),
                token: j
                    .get("token")
                    .and_then(|t| t.as_str())
                    .map(|t| t.to_string()),
                run_id: j.get("run_id")?.as_str()?.to_string(),
            }),
            "detach" => Some(Msg::Detach),
            "accepted" => Some(Msg::Accepted {
                run_id: j.get("run_id")?.as_str()?.to_string(),
            }),
            "event" => Some(Msg::Event {
                run_id: j.get("run_id")?.as_str()?.to_string(),
                event: j.get("event")?.clone(),
            }),
            _ => None,
        }
    }

    /// Rebuilds the [`TaskSpec`] carried by a `Task` message.
    pub fn task_spec(index: u64, params: &[(String, ParamValue)]) -> TaskSpec {
        TaskSpec { params: params.to_vec(), index: index as usize, exp: None }
    }
}

/// Writes one frame as JSON. The caller is responsible for serializing
/// access to the stream (frames must not interleave). Kept as the
/// explicit-JSON entry point: handshakes and anything that must stay
/// readable by pre-v3 peers goes through here.
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> io::Result<()> {
    write_frame_as(w, msg, WireFormat::Json)
}

/// Writes one frame in the requested payload format. Handshake frames
/// ([`Msg::Ready`], [`Msg::Hello`], [`Msg::Reject`], and the daemon
/// openers [`Msg::Submit`]/[`Msg::Attach`]) are pinned to JSON
/// regardless of `format` — a peer that has not finished negotiating must
/// be able to parse them, whatever it speaks.
pub fn write_frame_as(w: &mut impl Write, msg: &Msg, format: WireFormat) -> io::Result<()> {
    let handshake = matches!(
        msg,
        Msg::Ready { .. }
            | Msg::Hello { .. }
            | Msg::Reject { .. }
            | Msg::Submit { .. }
            | Msg::Attach { .. }
    );
    let payload = if format == WireFormat::Binary && !handshake {
        codec::encode(&msg.to_json())
    } else {
        msg.to_json().to_string().into_bytes()
    };
    if payload.len() > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds MAX_FRAME", payload.len()),
        ));
    }
    let len = (payload.len() as u32).to_be_bytes();
    w.write_all(&len)?;
    w.write_all(&payload)?;
    w.flush()
}

/// Reads one frame, auto-detecting the payload format per frame (a
/// leading [`codec::BINARY_MAGIC`] byte means binary, anything else is
/// JSON — the magic can never begin JSON text). Returns `Ok(None)` on a
/// clean EOF *before* the length prefix (the peer closed between
/// messages); EOF mid-frame, an oversized length, or an unparseable
/// payload are errors.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Msg>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside frame length",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let doc = if codec::is_binary(&payload) {
        codec::decode(&payload).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("frame not valid binary: {e}"))
        })?
    } else {
        let text = std::str::from_utf8(&payload).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("frame not utf-8: {e}"))
        })?;
        parse(text)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("frame not json: {e}")))?
    };
    Msg::from_json(&doc)
        .map(Some)
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "unknown message shape"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_f64, pv_int, pv_str};

    fn roundtrip_as(msg: &Msg, format: WireFormat) {
        let mut buf = Vec::new();
        write_frame_as(&mut buf, msg, format).unwrap();
        let mut cursor = &buf[..];
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(&back, msg, "{format:?} roundtrip");
        // stream fully consumed
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    fn roundtrip(msg: Msg) {
        roundtrip_as(&msg, WireFormat::Json);
        roundtrip_as(&msg, WireFormat::Binary);
    }

    fn ready(worker: u64, pid: u64, spawn: u64) -> Msg {
        Msg::Ready {
            worker,
            pid,
            spawn,
            protocol: PROTOCOL_VERSION,
            token: None,
            clock_us: None,
            exps: None,
        }
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(ready(3, 4242, 7));
        roundtrip(Msg::Ready {
            worker: 0,
            pid: 1,
            spawn: 0,
            protocol: PROTOCOL_VERSION,
            token: Some("s3cret".into()),
            clock_us: Some(123_456_789),
            exps: Some(vec!["echo".into(), "grid".into()]),
        });
        roundtrip(Msg::Goodbye);
        roundtrip(Msg::Reject { reason: "auth token mismatch".into() });
        roundtrip(Msg::Heartbeat { worker: 0, busy: Some(17) });
        roundtrip(Msg::Heartbeat { worker: 1, busy: None });
        roundtrip(Msg::Progress { index: 9, value: Json::int(5) });
        roundtrip(Msg::Outcome {
            index: 2,
            attempt: 1,
            duration_secs: 0.25,
            exec_start_us: Some(1_000_000),
            exec_end_us: Some(1_250_000),
            result: WireResult::Ok { value: Json::obj(vec![("accuracy", Json::Num(0.9))]) },
        });
        roundtrip(Msg::Outcome {
            index: 2,
            attempt: 3,
            duration_secs: 0.5,
            exec_start_us: None,
            exec_end_us: None,
            result: WireResult::Err { message: "kaboom".into(), panicked: true },
        });
        let mut settings = BTreeMap::new();
        settings.insert("n_fold".to_string(), Json::int(5));
        roundtrip(Msg::Hello {
            protocol: PROTOCOL_VERSION,
            version: "v2".into(),
            run_seed: u64::MAX, // exercises the string encoding
            settings,
            heartbeat_ms: 500,
            wire: WireFormat::Json,
        });
        roundtrip(Msg::Task {
            index: 7,
            attempt: 2,
            params: vec![
                ("model".into(), pv_str("SVC")),
                ("n".into(), pv_int(5)),
                ("lr".into(), pv_f64(0.5)),
            ],
            restored: Some(Json::int(3)),
            exp: None,
            exp_version: None,
        });
        roundtrip(Msg::Task {
            index: 8,
            attempt: 1,
            params: vec![("x".into(), pv_int(1))],
            restored: None,
            exp: Some("echo".into()),
            exp_version: Some("v1".into()),
        });
        roundtrip(Msg::Task {
            index: 0,
            attempt: 1,
            params: vec![],
            restored: None,
            exp: None,
            exp_version: None,
        });
        roundtrip(Msg::Outcome {
            index: 5,
            attempt: 1,
            duration_secs: 0.0,
            exec_start_us: None,
            exec_end_us: None,
            result: WireResult::Unsupported {
                message: "experiment 'echo' not registered here".into(),
            },
        });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::Submit {
            protocol: PROTOCOL_VERSION,
            token: Some("s3cret".into()),
            tenant: "alice".into(),
            matrix: Json::obj(vec![(
                "parameters",
                Json::obj(vec![("x", Json::Arr(vec![Json::int(1), Json::int(2)]))]),
            )]),
            exp: Some("echo".into()),
            version: Some("v1".into()),
            seed: u64::MAX, // exercises the string encoding
            label: Some("sweep-a".into()),
        });
        roundtrip(Msg::Submit {
            protocol: PROTOCOL_VERSION,
            token: None,
            tenant: "bob".into(),
            matrix: Json::obj(vec![]),
            exp: None,
            version: None,
            seed: 7,
            label: None,
        });
        roundtrip(Msg::Attach {
            protocol: PROTOCOL_VERSION,
            token: Some("s3cret".into()),
            run_id: "alice/run-0001".into(),
        });
        roundtrip(Msg::Attach { protocol: PROTOCOL_VERSION, token: None, run_id: "".into() });
        roundtrip(Msg::Detach);
        roundtrip(Msg::Accepted { run_id: "alice/run-0001".into() });
        roundtrip(Msg::Event {
            run_id: "alice/run-0001".into(),
            event: Json::obj(vec![("event", Json::str("run_complete"))]),
        });
    }

    #[test]
    fn task_params_preserve_declaration_order() {
        let msg = Msg::Task {
            index: 0,
            attempt: 1,
            params: vec![("z".into(), pv_int(1)), ("a".into(), pv_int(2))],
            restored: None,
            exp: None,
            exp_version: None,
        };
        let back = Msg::from_json(&msg.to_json()).unwrap();
        let Msg::Task { params, .. } = back else { panic!("not a task") };
        assert_eq!(params[0].0, "z");
        assert_eq!(params[1].0, "a");
    }

    #[test]
    fn handshake_frames_stay_json_even_in_binary_mode() {
        let hello = Msg::Hello {
            protocol: PROTOCOL_VERSION,
            version: "v1".into(),
            run_seed: 7,
            settings: BTreeMap::new(),
            heartbeat_ms: 100,
            wire: WireFormat::Binary,
        };
        let submit = Msg::Submit {
            protocol: PROTOCOL_VERSION,
            token: Some("t".into()),
            tenant: "a".into(),
            matrix: Json::obj(vec![]),
            exp: None,
            version: None,
            seed: 1,
            label: None,
        };
        let attach =
            Msg::Attach { protocol: PROTOCOL_VERSION, token: Some("t".into()), run_id: "r".into() };
        for msg in
            [ready(1, 2, 0), hello, Msg::Reject { reason: "nope".into() }, submit, attach]
        {
            let mut buf = Vec::new();
            write_frame_as(&mut buf, &msg, WireFormat::Binary).unwrap();
            // Payload (after the 4-byte prefix) must be JSON text — a v2
            // peer has to be able to parse the negotiation itself.
            assert_eq!(buf[4], b'{', "handshake payload must be JSON: {msg:?}");
            let mut cursor = &buf[..];
            assert_eq!(read_frame(&mut cursor).unwrap(), Some(msg));
        }
        // A data frame in binary mode really is binary.
        let mut buf = Vec::new();
        write_frame_as(&mut buf, &Msg::Shutdown, WireFormat::Binary).unwrap();
        assert_eq!(buf[4], codec::BINARY_MAGIC);
    }

    #[test]
    fn mixed_format_frames_interleave_on_one_stream() {
        let mut buf = Vec::new();
        write_frame_as(&mut buf, &Msg::Heartbeat { worker: 1, busy: None }, WireFormat::Binary)
            .unwrap();
        write_frame(&mut buf, &Msg::Shutdown).unwrap();
        write_frame_as(&mut buf, &Msg::Goodbye, WireFormat::Binary).unwrap();
        let mut cursor = &buf[..];
        assert_eq!(
            read_frame(&mut cursor).unwrap(),
            Some(Msg::Heartbeat { worker: 1, busy: None })
        );
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Msg::Shutdown));
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Msg::Goodbye));
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
    }

    #[test]
    fn corrupt_binary_payload_is_an_error() {
        // Valid length prefix, magic byte, then garbage.
        let payload = [codec::BINARY_MAGIC, 0x77, 0x01];
        let mut buf = Vec::new();
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(&payload);
        let mut cursor = &buf[..];
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Truncated binary payload (length prefix honest, document cut).
        let mut full = Vec::new();
        write_frame_as(&mut full, &Msg::Progress { index: 1, value: Json::int(9) }, WireFormat::Binary)
            .unwrap();
        let body = &full[4..full.len() - 1];
        let mut buf = Vec::new();
        buf.extend_from_slice(&(body.len() as u32).to_be_bytes());
        buf.extend_from_slice(body);
        let mut cursor = &buf[..];
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn v2_hello_without_wire_field_parses_as_binary_default() {
        // A v2 supervisor's Hello has no "wire" key. It must parse, and
        // the Binary default is inert because the worker also requires
        // protocol >= 3 before switching formats.
        let doc = parse(
            r#"{"msg":"hello","protocol":2,"version":"v1","run_seed":"7","settings":{},"heartbeat_ms":100}"#,
        )
        .unwrap();
        let Some(Msg::Hello { protocol, wire, run_seed, .. }) = Msg::from_json(&doc) else {
            panic!("v2 hello must parse");
        };
        assert_eq!(protocol, 2);
        assert_eq!(wire, WireFormat::Binary);
        assert_eq!(run_seed, 7);
    }

    #[test]
    fn wire_format_arg_spellings() {
        assert_eq!(WireFormat::parse_arg("json"), Some(WireFormat::Json));
        assert_eq!(WireFormat::parse_arg("binary"), Some(WireFormat::Binary));
        assert_eq!(WireFormat::parse_arg("msgpack"), None);
        assert_eq!(WireFormat::default(), WireFormat::Binary);
        for f in [WireFormat::Json, WireFormat::Binary] {
            assert_eq!(WireFormat::parse_arg(f.as_str()), Some(f));
        }
    }

    #[test]
    fn v3_outcome_without_exec_timestamps_parses_with_none() {
        // A v3 worker's outcome frame has no exec timestamp fields; the
        // supervisor must parse it and synthesize a timeline from
        // duration_secs instead of failing the attempt.
        let doc = parse(
            r#"{"msg":"outcome","index":4,"attempt":1,"duration_secs":0.5,"ok":true,"value":1}"#,
        )
        .unwrap();
        let Some(Msg::Outcome { exec_start_us, exec_end_us, duration_secs, .. }) =
            Msg::from_json(&doc)
        else {
            panic!("v3 outcome must parse");
        };
        assert_eq!(exec_start_us, None);
        assert_eq!(exec_end_us, None);
        assert_eq!(duration_secs, 0.5);
    }

    #[test]
    fn v3_ready_without_clock_parses_with_none() {
        let doc = parse(r#"{"msg":"ready","worker":1,"pid":2,"spawn":0,"protocol":3}"#).unwrap();
        let Some(Msg::Ready { protocol, clock_us, .. }) = Msg::from_json(&doc) else {
            panic!("v3 ready must parse");
        };
        assert_eq!(protocol, 3);
        assert_eq!(clock_us, None);
    }

    #[test]
    fn v4_ready_without_exps_parses_with_none() {
        // A v4 worker advertises no capability list; the supervisor
        // must treat it as "unnamed tasks only", not reject it.
        let doc = parse(r#"{"msg":"ready","worker":1,"pid":2,"spawn":0,"protocol":4}"#).unwrap();
        let Some(Msg::Ready { protocol, exps, .. }) = Msg::from_json(&doc) else {
            panic!("v4 ready must parse");
        };
        assert_eq!(protocol, 4);
        assert_eq!(exps, None);
    }

    #[test]
    fn v4_task_without_exp_parses_with_none() {
        let doc = parse(
            r#"{"msg":"task","index":3,"attempt":1,"params":[["x",1]],"restored":null}"#,
        )
        .unwrap();
        let Some(Msg::Task { exp, exp_version, .. }) = Msg::from_json(&doc) else {
            panic!("v4 task must parse");
        };
        assert_eq!(exp, None);
        assert_eq!(exp_version, None);
    }

    #[test]
    fn unsupported_outcome_is_distinct_from_err() {
        // An ok:false outcome without the unsupported marker must stay
        // an Err (v4 workers never send the marker), and with it must
        // parse as Unsupported.
        let doc = parse(
            r#"{"msg":"outcome","index":1,"attempt":1,"duration_secs":0.0,"ok":false,"message":"m","panicked":false}"#,
        )
        .unwrap();
        let Some(Msg::Outcome { result, .. }) = Msg::from_json(&doc) else {
            panic!("outcome must parse");
        };
        assert_eq!(result, WireResult::Err { message: "m".into(), panicked: false });
        let doc = parse(
            r#"{"msg":"outcome","index":1,"attempt":1,"duration_secs":0.0,"ok":false,"unsupported":true,"message":"no echo"}"#,
        )
        .unwrap();
        let Some(Msg::Outcome { result, .. }) = Msg::from_json(&doc) else {
            panic!("outcome must parse");
        };
        assert_eq!(result, WireResult::Unsupported { message: "no echo".into() });
    }

    #[test]
    fn submit_without_protocol_parses_as_zero() {
        // A submit frame from a peer too old to know it must carry a
        // protocol still parses — with protocol 0, which the daemon then
        // rejects with a version message, never a hang or a parse error.
        let doc = parse(r#"{"msg":"submit","tenant":"a","matrix":{},"seed":"7"}"#).unwrap();
        let Some(Msg::Submit { protocol, token, exp, version, label, seed, .. }) =
            Msg::from_json(&doc)
        else {
            panic!("minimal submit must parse");
        };
        assert_eq!(protocol, 0);
        assert_eq!(token, None);
        assert_eq!(exp, None);
        assert_eq!(version, None);
        assert_eq!(label, None);
        assert_eq!(seed, 7);
    }

    #[test]
    fn daemon_frames_parse_from_raw_json() {
        // The daemon frames are JSON-pinned handshakes (Submit/Attach)
        // or stream frames whose raw shapes are part of the v6 contract;
        // parse them from hand-written text so the wire shape can't
        // drift silently.
        for raw in [
            r#"{"msg":"accepted","run_id":"r"}"#,
            r#"{"msg":"event","run_id":"r","event":{}}"#,
            r#"{"msg":"detach"}"#,
            r#"{"msg":"attach","protocol":6,"run_id":""}"#,
        ] {
            let doc = parse(raw).unwrap();
            assert!(Msg::from_json(&doc).is_some(), "v6 reader must parse {raw}");
        }
    }

    #[test]
    fn pre_v2_ready_parses_with_zero_protocol() {
        // A frame from an old worker (no protocol/token fields) must still
        // parse — with protocol 0, which an accepting pool then rejects
        // with a version message instead of a generic parse error.
        let doc = parse(r#"{"msg":"ready","worker":1,"pid":2,"spawn":3}"#).unwrap();
        let Some(Msg::Ready { protocol, token, .. }) = Msg::from_json(&doc) else {
            panic!("pre-v2 ready must parse");
        };
        assert_eq!(protocol, 0);
        assert_eq!(token, None);
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Msg::Shutdown).unwrap();
        write_frame(&mut buf, &ready(1, 2, 0)).unwrap();
        let mut cursor = &buf[..];
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Msg::Shutdown));
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(ready(1, 2, 0)));
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
    }

    #[test]
    fn truncated_frame_is_an_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &ready(1, 2, 0)).unwrap();
        buf.truncate(buf.len() - 3);
        let mut cursor = &buf[..];
        assert!(read_frame(&mut cursor).is_err());
        // eof inside the length prefix is also an error
        let mut short: &[u8] = &[0u8, 0];
        assert!(read_frame(&mut short).is_err());
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        buf.extend_from_slice(b"xx");
        let mut cursor = &buf[..];
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn garbage_payload_rejected() {
        let payload = b"{not json";
        let mut buf = Vec::new();
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(payload);
        let mut cursor = &buf[..];
        assert!(read_frame(&mut cursor).is_err());
        // valid json, unknown shape
        let payload = b"{\"msg\":\"martian\"}";
        let mut buf = Vec::new();
        buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        buf.extend_from_slice(payload);
        let mut cursor = &buf[..];
        assert!(read_frame(&mut cursor).is_err());
    }
}
