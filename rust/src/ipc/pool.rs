//! The standing worker pool: inbound registration, auth, and leasing.
//!
//! The spawned-worker backend creates workers per run; the distributed
//! backend inverts the arrow. A [`WorkerPool`] **listens** (normally on
//! TCP — see [`crate::ipc::transport`]) and standing workers — `memento
//! serve` processes on this or other machines, or
//! [`crate::ipc::worker::serve_remote`] threads — *connect in* and
//! register. The pool authenticates each registration (shared token +
//! protocol version, checked against the worker's `Ready` frame, refused
//! with a `Reject` frame), then parks the connection in a queue.
//! Supervisor slots [`WorkerPool::lease`] registered workers one at a
//! time; a leased worker serves task attempts until the run ends
//! (`Shutdown`), after which a standing worker reconnects and re-registers
//! for the next lease.
//!
//! Because the pool is just a listener plus a queue, it naturally
//! **outlives a single run**: create it once
//! ([`WorkerPool::listen`]), hand it to any number of consecutive
//! `Memento` runs (`with_worker_pool`), and the same worker processes are
//! reused — worker spawn cost is paid once, not per run, which is what
//! makes many-small-runs workloads cheap.
//!
//! # Sharing one pool across concurrent runs
//!
//! Runs may also lease **concurrently** (the daemon multiplexes every
//! active run onto one pool). Each run identifies itself with a *lease
//! ticket* ([`WorkerPool::ticket`]) and waits via [`WorkerPool::lease_as`];
//! grants are **directed**: a parked registration is handed to exactly one
//! waiter (moved into its delivery cell under the pool mutex, so two
//! runs can never double-lease one worker), and when several tickets are
//! waiting the least-recently-granted ticket wins — round-robin
//! fair-share across runs, FIFO within a run. Every granted
//! [`Registration`] carries a [`LeaseToken`] whose drop returns the
//! worker's capacity signal; [`Lease::TimedOut`]'s `busy` flag lets a
//! starved run distinguish *contention* (workers exist, all leased by
//! other runs — keep waiting, charge nobody) from *absence* (nothing
//! registered — a real acquisition failure).
//!
//! # Trust model
//!
//! A TCP listener is reachable by anything that can route to it, so a
//! token is **required** for TCP pools: a registration whose `Ready`
//! frame carries the wrong token (or an incompatible protocol version) is
//! answered with `Reject{reason}` and dropped before it can observe
//! anything about the run — settings, seeds, and the experiment version
//! only travel in `Hello`, which is sent at lease time to authenticated
//! workers. The token is a shared secret distributed out of band (the CLI
//! reads it from `--token-file`); transport encryption is out of scope —
//! run over a trusted network or a tunnel.

use crate::coordinator::error::MementoError;
use crate::ipc::proto::{read_frame, write_frame, Msg, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION};
use crate::ipc::transport::{Endpoint, Transport, WireListener, WireStream};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Configuration for [`WorkerPool::listen`].
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Shared auth token workers must present. **Required** for
    /// [`Transport::Tcp`] (listening without one is refused); optional
    /// for [`Transport::Unix`], where filesystem permissions gate access.
    pub token: Option<String>,
    /// How long a fresh connection gets to deliver its `Ready` frame
    /// before being dropped (a silent connection must not wedge the
    /// acceptor).
    pub handshake_timeout: Duration,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            token: None,
            handshake_timeout: Duration::from_secs(5),
        }
    }
}

/// One authenticated, registered worker connection waiting for (or held
/// by) a lease.
pub struct Registration {
    /// The connection, handshake already consumed (`Ready` read and
    /// verified; `Hello` not yet sent — that happens at lease time, since
    /// run configuration is per lease).
    pub stream: Box<dyn WireStream>,
    /// Pool-assigned registration sequence number (unique per pool).
    pub member: u64,
    /// The id the worker reported about itself (diagnostics only).
    pub worker: u64,
    /// The worker's OS process id, as self-reported.
    pub pid: u64,
    /// The protocol version the worker declared in `Ready` — within
    /// `MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION` (anything else was
    /// rejected). The supervisor keeps post-handshake frames to JSON for
    /// pre-v3 registrants.
    pub protocol: u64,
    /// Estimated offset from this worker's monotonic clock to the pool
    /// host's ([`crate::obs::trace::monotonic_us`] here minus the
    /// worker's `clock_us`, sampled at `Ready` receipt — error bounded by
    /// the one-way handshake latency). `None` for pre-v4 workers, whose
    /// exec timestamps are synthesized supervisor-side instead.
    pub clock_offset_us: Option<i64>,
    /// The named experiments this worker's registry advertised in `Ready`
    /// (v5+). `None` for pre-v5 workers; the supervisor routes only
    /// *unnamed* tasks to those. `Some(vec![])` is a v5 worker that
    /// registers no names — same routing, but declared rather than
    /// assumed.
    pub exps: Option<Vec<String>>,
    /// Busy-accounting guard, set at grant time. Keep it alive for as
    /// long as the connection is in use (move it alongside the stream);
    /// its drop tells the pool this worker's capacity is no longer held,
    /// which is what [`Lease::TimedOut`]'s `busy` flag reads. `None`
    /// only before the registration has been granted.
    pub lease: Option<LeaseToken>,
}

/// RAII guard pairing one granted [`Registration`] with the pool's busy
/// accounting: while it lives the worker counts as leased, and dropping
/// it (connection closed, run finished, registration discarded as stale)
/// releases that count. Created only by the pool at grant time.
pub struct LeaseToken {
    shared: Arc<PoolShared>,
}

impl Drop for LeaseToken {
    fn drop(&mut self) {
        let mut state = self.shared.state.lock().unwrap();
        state.leased = state.leased.saturating_sub(1);
        drop(state);
        self.shared.cv.notify_all();
    }
}

impl std::fmt::Debug for LeaseToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LeaseToken").finish_non_exhaustive()
    }
}

/// Outcome of one [`WorkerPool::lease_as`] wait.
pub enum Lease {
    /// A registered worker was granted to this ticket.
    Granted(Registration),
    /// No grant arrived within the deadline.
    TimedOut {
        /// `true` when at least one worker was leased out (by any
        /// ticket) at the deadline — the pool is *contended*, not empty,
        /// and the caller should keep waiting rather than treat this as
        /// an acquisition failure. `false` means nothing is registered
        /// at all.
        busy: bool,
    },
    /// The pool shut down; no grant will ever arrive.
    Closed,
}

/// One parked `lease_as` call: grants are *directed* — the granting side
/// moves a registration into exactly one waiter's delivery cell, so a
/// registration can never be observed (let alone leased) by two waiters.
struct Waiter {
    id: u64,
    ticket: u64,
    delivery: Option<Registration>,
}

struct PoolState {
    queue: VecDeque<Registration>,
    /// Parked `lease_as` calls, in arrival order (the round-robin
    /// tie-break).
    waiters: Vec<Waiter>,
    /// Per-ticket grant recency: the `grant_counter` value of the
    /// ticket's most recent grant. Least-recently-granted wins the next
    /// registration.
    last_grant: HashMap<u64, u64>,
    grant_counter: u64,
    /// Registrations currently granted and alive (their [`LeaseToken`]
    /// not yet dropped).
    leased: usize,
    /// Set once the acceptor thread exits; leases then fail fast instead
    /// of waiting out their full deadline on a dead pool.
    closed: bool,
}

/// Innards shared between the pool handle and its acceptor thread. Kept
/// separate from [`WorkerPool`] so the acceptor never holds the public
/// handle — otherwise the handle's `Drop` (which stops the acceptor)
/// could never run.
struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
    registered: AtomicU64,
    rejected: AtomicU64,
    waiter_seq: AtomicU64,
    tickets: AtomicU64,
}

/// A standing, authenticated pool of registered remote workers (see the
/// [module docs](self) for the lifecycle).
pub struct WorkerPool {
    endpoint: Endpoint,
    shared: Arc<PoolShared>,
    stop: Arc<AtomicBool>,
    acceptor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("endpoint", &self.endpoint.to_string())
            .field("registered", &self.registered_count())
            .field("rejected", &self.rejected_count())
            .finish()
    }
}

impl WorkerPool {
    /// Binds the transport and starts accepting worker registrations on a
    /// background thread. The returned handle is shared (`Arc`) because
    /// supervisor slots lease from it concurrently — and because keeping
    /// it across `Memento` runs is exactly how worker processes get
    /// reused.
    pub fn listen(
        transport: &Transport,
        opts: PoolOptions,
    ) -> Result<Arc<WorkerPool>, MementoError> {
        if matches!(transport, Transport::Tcp { .. }) && opts.token.is_none() {
            return Err(MementoError::config(
                "a TCP worker pool requires a shared auth token (anyone who can \
                 reach the port could otherwise register as a worker)",
            ));
        }
        let (listener, sock_dir) = transport
            .bind()
            .map_err(|e| MementoError::ipc(format!("bind {transport:?}: {e}")))?;
        let endpoint = listener.endpoint();
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                waiters: Vec::new(),
                last_grant: HashMap::new(),
                grant_counter: 0,
                leased: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            registered: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            waiter_seq: AtomicU64::new(0),
            tickets: AtomicU64::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("memento-pool-accept".into())
                .spawn(move || {
                    // The Unix socket's temp dir (if any) lives and dies
                    // with the acceptor.
                    let _sock_dir = sock_dir;
                    shared.accept_loop(listener, opts, stop);
                })
                .map_err(|e| MementoError::ipc(format!("spawn pool acceptor: {e}")))?
        };
        Ok(Arc::new(WorkerPool {
            endpoint,
            shared,
            stop,
            acceptor: Mutex::new(Some(handle)),
        }))
    }

    /// The address workers should connect to — with a `:0` bind request
    /// this carries the OS-assigned port, so it is what a `memento serve
    /// --connect` invocation (or [`crate::ipc::worker::serve_remote`])
    /// needs.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// Takes the next registered worker, waiting up to `timeout` for one
    /// to register. `None` means no worker became available (or the pool
    /// shut down) — callers treat that like a failed worker spawn.
    /// Equivalent to [`WorkerPool::lease_as`] under the shared default
    /// ticket, with the timeout classification collapsed away — single-run
    /// callers don't need it.
    pub fn lease(&self, timeout: Duration) -> Option<Registration> {
        match self.lease_as(0, timeout) {
            Lease::Granted(reg) => Some(reg),
            Lease::TimedOut { .. } | Lease::Closed => None,
        }
    }

    /// Allocates a fresh lease ticket. Every concurrent run (or any
    /// other party leasing from this pool) should hold its own ticket:
    /// grants round-robin across tickets, so one run submitting faster
    /// than another cannot monopolize registrations.
    pub fn ticket(&self) -> u64 {
        self.shared.tickets.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Waits up to `timeout` for a registered worker to be granted to
    /// `ticket`. Grants are directed (a registration is moved to exactly
    /// one waiter, under the pool mutex) and fair: when several tickets
    /// wait, the least-recently-granted one receives the next
    /// registration, with arrival order breaking ties.
    pub fn lease_as(&self, ticket: u64, timeout: Duration) -> Lease {
        let deadline = Instant::now() + timeout;
        let id = self.shared.waiter_seq.fetch_add(1, Ordering::SeqCst) + 1;
        let mut state = self.shared.state.lock().unwrap();
        state.waiters.push(Waiter { id, ticket, delivery: None });
        self.shared.grant_locked(&mut state);
        loop {
            let pos = state
                .waiters
                .iter()
                .position(|w| w.id == id)
                .expect("own waiter entry present until removed here");
            if state.waiters[pos].delivery.is_some() {
                let w = state.waiters.remove(pos);
                return Lease::Granted(w.delivery.unwrap());
            }
            if state.closed {
                state.waiters.remove(pos);
                return Lease::Closed;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                let busy = state.leased > 0;
                state.waiters.remove(pos);
                return Lease::TimedOut { busy };
            }
            let (st, _timeout) = self.shared.cv.wait_timeout(state, remaining).unwrap();
            state = st;
        }
    }

    /// Registered workers currently queued (not leased).
    pub fn available(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Granted registrations whose [`LeaseToken`] is still alive — the
    /// workers currently held by runs.
    pub fn leased_count(&self) -> usize {
        self.shared.state.lock().unwrap().leased
    }

    /// `lease_as` calls currently parked waiting for a grant.
    pub fn waiting_count(&self) -> usize {
        self.shared.state.lock().unwrap().waiters.len()
    }

    /// Total successful registrations over the pool's lifetime. A
    /// standing worker counts once per (re)connection, so this growing
    /// across runs is the pool-reuse story working.
    pub fn registered_count(&self) -> u64 {
        self.shared.registered.load(Ordering::SeqCst)
    }

    /// Registrations refused (bad token or protocol mismatch).
    pub fn rejected_count(&self) -> u64 {
        self.shared.rejected.load(Ordering::SeqCst)
    }

    /// Stops accepting registrations and drops every queued connection
    /// (their workers observe EOF-before-`Hello` and retry or give up per
    /// their own options). Called by `Drop`; idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.lock().unwrap().take() {
            let _ = h.join();
        }
        let mut state = self.shared.state.lock().unwrap();
        state.closed = true;
        state.queue.clear();
        self.shared.cv.notify_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl PoolShared {
    fn accept_loop(
        self: Arc<Self>,
        listener: Box<dyn WireListener>,
        opts: PoolOptions,
        stop: Arc<AtomicBool>,
    ) {
        crate::ipc::transport::poll_accept(listener, &stop, |stream| {
            // Handshake each connection on its own short-lived thread: the
            // TCP listener is reachable by untrusted peers, and a silent
            // connection gets `handshake_timeout` to produce its `Ready` —
            // serializing that wait here would let one garbage connection
            // stall every legitimate registration behind it.
            let shared = Arc::clone(&self);
            let opts = opts.clone();
            let spawned = std::thread::Builder::new()
                .name("memento-pool-handshake".into())
                .spawn(move || PoolShared::register(&shared, stream, &opts));
            drop(spawned); // spawn failure just drops the connection
        });
        let mut state = self.state.lock().unwrap();
        state.closed = true;
        state.queue.clear();
        self.cv.notify_all();
    }

    /// Hands parked registrations to parked waiters, least-recently-
    /// granted ticket first (arrival order breaks ties). The only place
    /// a registration leaves the queue for a lease: the move into the
    /// winning waiter's delivery cell happens under the state mutex, so
    /// concurrent runs can never double-lease one worker.
    fn grant_locked(self: &Arc<Self>, state: &mut PoolState) {
        loop {
            if state.queue.is_empty() {
                return;
            }
            let mut best: Option<usize> = None;
            for (i, w) in state.waiters.iter().enumerate() {
                if w.delivery.is_some() {
                    continue;
                }
                let key = state.last_grant.get(&w.ticket).copied().unwrap_or(0);
                let better = match best {
                    None => true,
                    Some(b) => {
                        key < state
                            .last_grant
                            .get(&state.waiters[b].ticket)
                            .copied()
                            .unwrap_or(0)
                    }
                };
                if better {
                    best = Some(i);
                }
            }
            let Some(i) = best else { return };
            let mut reg = state.queue.pop_front().unwrap();
            reg.lease = Some(LeaseToken { shared: Arc::clone(self) });
            state.grant_counter += 1;
            let ticket = state.waiters[i].ticket;
            state.last_grant.insert(ticket, state.grant_counter);
            state.leased += 1;
            state.waiters[i].delivery = Some(reg);
        }
    }

    /// Handshakes one inbound connection: read `Ready`, verify protocol
    /// and token, queue it — or answer `Reject` and drop it.
    fn register(self: &Arc<Self>, stream: Box<dyn WireStream>, opts: &PoolOptions) {
        // The handshake must arrive promptly; a silent connection is
        // dropped rather than wedging the acceptor.
        let _ = stream.set_stream_read_timeout(Some(opts.handshake_timeout));
        let mut reader = stream;
        let ready = match read_frame(&mut reader) {
            Ok(Some(m)) => m,
            _ => return, // silent/garbled connection: drop without ceremony
        };
        let Msg::Ready { worker, pid, protocol, token, clock_us, exps, .. } = ready else {
            return;
        };
        let clock_offset_us =
            clock_us.map(|c| crate::obs::trace::monotonic_us() as i64 - c as i64);
        let refusal = if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&protocol) {
            Some(format!(
                "protocol mismatch: pool speaks v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION}, \
                 worker speaks v{protocol}"
            ))
        } else if let Some(required) = &opts.token {
            let ok = token.as_deref().is_some_and(|t| {
                crate::util::sha256::constant_time_eq(t.as_bytes(), required.as_bytes())
            });
            if ok {
                None
            } else {
                Some("auth token mismatch".to_string())
            }
        } else {
            None
        };
        if let Some(reason) = refusal {
            self.rejected.fetch_add(1, Ordering::SeqCst);
            eprintln!(
                "memento pool: rejected registration from {} (pid {pid}): {reason}",
                reader.peer_label()
            );
            let _ = write_frame(&mut reader, &Msg::Reject { reason });
            let _ = reader.shutdown_both();
            return;
        }
        // Authenticated: normalize the stream (no read deadline — a
        // queued worker may wait arbitrarily long for its lease) and park
        // it for the next lease.
        let _ = reader.set_stream_read_timeout(None);
        let mut state = self.state.lock().unwrap();
        if state.closed {
            // The pool shut down while this handshake thread was mid
            // flight; dropping the connection tells the worker to retry
            // elsewhere (EOF before Hello).
            return;
        }
        let member = self.registered.fetch_add(1, Ordering::SeqCst) + 1;
        state.queue.push_back(Registration {
            stream: reader,
            member,
            worker,
            pid,
            protocol,
            clock_offset_us,
            exps,
            lease: None,
        });
        self.grant_locked(&mut state);
        drop(state);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tcp_pool(token: &str) -> Arc<WorkerPool> {
        WorkerPool::listen(
            &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
            PoolOptions { token: Some(token.to_string()), ..PoolOptions::default() },
        )
        .unwrap()
    }

    fn send_ready(endpoint: &Endpoint, protocol: u64, token: Option<&str>) -> Box<dyn WireStream> {
        let mut stream = endpoint.connect().unwrap();
        write_frame(
            &mut stream,
            &Msg::Ready {
                worker: 9,
                pid: 1234,
                spawn: 0,
                protocol,
                token: token.map(|t| t.to_string()),
                clock_us: if protocol >= 4 { Some(1) } else { None },
                exps: if protocol >= 5 {
                    Some(vec!["echo".to_string()])
                } else {
                    None
                },
            },
        )
        .unwrap();
        stream
    }

    #[test]
    fn tcp_pool_requires_a_token() {
        let err = WorkerPool::listen(
            &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
            PoolOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("token"), "{err}");
    }

    #[test]
    fn good_token_registers_and_leases() {
        let pool = tcp_pool("s3cret");
        let _stream = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let reg = pool.lease(Duration::from_secs(5)).expect("worker registers");
        assert_eq!(reg.worker, 9);
        assert_eq!(reg.pid, 1234);
        assert_eq!(reg.member, 1);
        assert_eq!(reg.protocol, PROTOCOL_VERSION);
        assert!(reg.clock_offset_us.is_some(), "v4 ready carries a clock sample");
        assert_eq!(
            reg.exps.as_deref(),
            Some(&["echo".to_string()][..]),
            "v5 ready carries the capability list"
        );
        assert_eq!(pool.registered_count(), 1);
        assert_eq!(pool.rejected_count(), 0);
    }

    #[test]
    fn v2_worker_still_registers() {
        // A JSON-only v2 worker is frame-compatible; the pool admits it
        // and records its version so the supervisor sticks to JSON.
        let pool = tcp_pool("s3cret");
        let _stream = send_ready(pool.endpoint(), MIN_PROTOCOL_VERSION, Some("s3cret"));
        let reg = pool.lease(Duration::from_secs(5)).expect("v2 worker registers");
        assert_eq!(reg.protocol, MIN_PROTOCOL_VERSION);
        assert_eq!(reg.clock_offset_us, None, "pre-v4 ready has no clock sample");
        assert_eq!(reg.exps, None, "pre-v5 ready has no capability list");
        assert_eq!(pool.rejected_count(), 0);
    }

    #[test]
    fn pre_v2_worker_is_rejected() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), 1, Some("s3cret"));
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        assert!(
            matches!(answer, Msg::Reject { ref reason } if reason.contains("protocol")),
            "{answer:?}"
        );
        assert_eq!(pool.rejected_count(), 1);
    }

    #[test]
    fn bad_token_is_rejected_with_a_reason() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("wrong"));
        // The worker hears an explicit Reject, not just a closed socket.
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        let reason = match answer {
            Msg::Reject { reason } => reason,
            other => panic!("expected Reject, got {other:?}"),
        };
        assert!(reason.contains("token"), "{reason}");
        // And the pool never offers it for lease.
        assert!(pool.lease(Duration::from_millis(100)).is_none());
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(pool.registered_count(), 0);
    }

    #[test]
    fn protocol_mismatch_is_rejected() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), PROTOCOL_VERSION + 1, Some("s3cret"));
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        assert!(
            matches!(answer, Msg::Reject { ref reason } if reason.contains("protocol")),
            "{answer:?}"
        );
        assert_eq!(pool.rejected_count(), 1);
    }

    #[test]
    fn lease_times_out_on_an_empty_pool() {
        let pool = tcp_pool("s3cret");
        let started = Instant::now();
        assert!(pool.lease(Duration::from_millis(80)).is_none());
        assert!(started.elapsed() >= Duration::from_millis(80));
    }

    #[test]
    fn shutdown_fails_leases_fast() {
        let pool = tcp_pool("s3cret");
        pool.shutdown();
        let started = Instant::now();
        assert!(pool.lease(Duration::from_secs(30)).is_none());
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "a closed pool must not wait out the full lease deadline"
        );
    }

    #[test]
    fn registrations_queue_in_arrival_order() {
        let pool = tcp_pool("s3cret");
        let _a = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let first = pool.lease(Duration::from_secs(5)).unwrap();
        let _b = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let second = pool.lease(Duration::from_secs(5)).unwrap();
        assert_eq!((first.member, second.member), (1, 2));
        assert_eq!(pool.registered_count(), 2);
    }

    #[test]
    fn concurrent_lessees_never_double_lease_a_worker() {
        // Two supervisors racing on one pool must each receive a
        // *distinct* registration — the directed handoff moves each
        // registration into exactly one waiter's delivery cell.
        let pool = tcp_pool("s3cret");
        let _a = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let _b = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let ticket = pool.ticket();
                match pool.lease_as(ticket, Duration::from_secs(10)) {
                    Lease::Granted(reg) => reg.member,
                    _ => panic!("both lessees must be granted"),
                }
            }));
        }
        let mut members: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        members.sort_unstable();
        assert_eq!(members, vec![1, 2], "each registration granted exactly once");
        assert_eq!(pool.leased_count(), 2);
    }

    #[test]
    fn grants_round_robin_across_tickets() {
        let pool = tcp_pool("s3cret");
        let t1 = pool.ticket();
        let t2 = pool.ticket();
        // Establish grant recency: t1 was granted before t2.
        let _a = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let held1 = match pool.lease_as(t1, Duration::from_secs(5)) {
            Lease::Granted(reg) => reg,
            _ => panic!("t1 grant"),
        };
        let _b = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let held2 = match pool.lease_as(t2, Duration::from_secs(5)) {
            Lease::Granted(reg) => reg,
            _ => panic!("t2 grant"),
        };
        assert_eq!((held1.member, held2.member), (1, 2));
        // Park both tickets, then register two more workers: the
        // least-recently-granted ticket (t1) must win the first one.
        let w1 = {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || match pool.lease_as(t1, Duration::from_secs(10)) {
                Lease::Granted(reg) => reg.member,
                _ => panic!("t1 regrant"),
            })
        };
        let w2 = {
            let pool = Arc::clone(&pool);
            std::thread::spawn(move || match pool.lease_as(t2, Duration::from_secs(10)) {
                Lease::Granted(reg) => reg.member,
                _ => panic!("t2 regrant"),
            })
        };
        let parked = Instant::now();
        while pool.waiting_count() < 2 {
            assert!(parked.elapsed() < Duration::from_secs(5), "waiters must park");
            std::thread::sleep(Duration::from_millis(2));
        }
        let _c = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let granted = Instant::now();
        while pool.leased_count() < 3 {
            assert!(granted.elapsed() < Duration::from_secs(5), "third grant must land");
            std::thread::sleep(Duration::from_millis(2));
        }
        let _d = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        assert_eq!(w1.join().unwrap(), 3, "least-recently-granted ticket wins first");
        assert_eq!(w2.join().unwrap(), 4);
    }

    #[test]
    fn busy_timeout_is_distinct_from_an_empty_pool() {
        let pool = tcp_pool("s3cret");
        let t = pool.ticket();
        // Nothing registered: timeout reports an *empty* pool.
        assert!(matches!(
            pool.lease_as(t, Duration::from_millis(50)),
            Lease::TimedOut { busy: false }
        ));
        // One worker, leased out: timeout reports *contention*.
        let _a = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let held = pool.lease(Duration::from_secs(5)).expect("grant");
        assert!(held.lease.is_some(), "granted registrations carry a lease token");
        assert!(matches!(
            pool.lease_as(t, Duration::from_millis(50)),
            Lease::TimedOut { busy: true }
        ));
        // Dropping the held registration releases the busy accounting.
        drop(held);
        assert_eq!(pool.leased_count(), 0);
        assert!(matches!(
            pool.lease_as(t, Duration::from_millis(50)),
            Lease::TimedOut { busy: false }
        ));
    }
}
