//! The standing worker pool: inbound registration, auth, and leasing.
//!
//! The spawned-worker backend creates workers per run; the distributed
//! backend inverts the arrow. A [`WorkerPool`] **listens** (normally on
//! TCP — see [`crate::ipc::transport`]) and standing workers — `memento
//! serve` processes on this or other machines, or
//! [`crate::ipc::worker::serve_remote`] threads — *connect in* and
//! register. The pool authenticates each registration (shared token +
//! protocol version, checked against the worker's `Ready` frame, refused
//! with a `Reject` frame), then parks the connection in a queue.
//! Supervisor slots [`WorkerPool::lease`] registered workers one at a
//! time; a leased worker serves task attempts until the run ends
//! (`Shutdown`), after which a standing worker reconnects and re-registers
//! for the next lease.
//!
//! Because the pool is just a listener plus a queue, it naturally
//! **outlives a single run**: create it once
//! ([`WorkerPool::listen`]), hand it to any number of consecutive
//! `Memento` runs (`with_worker_pool`), and the same worker processes are
//! reused — worker spawn cost is paid once, not per run, which is what
//! makes many-small-runs workloads cheap.
//!
//! # Trust model
//!
//! A TCP listener is reachable by anything that can route to it, so a
//! token is **required** for TCP pools: a registration whose `Ready`
//! frame carries the wrong token (or an incompatible protocol version) is
//! answered with `Reject{reason}` and dropped before it can observe
//! anything about the run — settings, seeds, and the experiment version
//! only travel in `Hello`, which is sent at lease time to authenticated
//! workers. The token is a shared secret distributed out of band (the CLI
//! reads it from `--token-file`); transport encryption is out of scope —
//! run over a trusted network or a tunnel.

use crate::coordinator::error::MementoError;
use crate::ipc::proto::{read_frame, write_frame, Msg, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION};
use crate::ipc::transport::{Endpoint, Transport, WireListener, WireStream};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Configuration for [`WorkerPool::listen`].
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Shared auth token workers must present. **Required** for
    /// [`Transport::Tcp`] (listening without one is refused); optional
    /// for [`Transport::Unix`], where filesystem permissions gate access.
    pub token: Option<String>,
    /// How long a fresh connection gets to deliver its `Ready` frame
    /// before being dropped (a silent connection must not wedge the
    /// acceptor).
    pub handshake_timeout: Duration,
}

impl Default for PoolOptions {
    fn default() -> Self {
        PoolOptions {
            token: None,
            handshake_timeout: Duration::from_secs(5),
        }
    }
}

/// One authenticated, registered worker connection waiting for (or held
/// by) a lease.
pub struct Registration {
    /// The connection, handshake already consumed (`Ready` read and
    /// verified; `Hello` not yet sent — that happens at lease time, since
    /// run configuration is per lease).
    pub stream: Box<dyn WireStream>,
    /// Pool-assigned registration sequence number (unique per pool).
    pub member: u64,
    /// The id the worker reported about itself (diagnostics only).
    pub worker: u64,
    /// The worker's OS process id, as self-reported.
    pub pid: u64,
    /// The protocol version the worker declared in `Ready` — within
    /// `MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION` (anything else was
    /// rejected). The supervisor keeps post-handshake frames to JSON for
    /// pre-v3 registrants.
    pub protocol: u64,
    /// Estimated offset from this worker's monotonic clock to the pool
    /// host's ([`crate::obs::trace::monotonic_us`] here minus the
    /// worker's `clock_us`, sampled at `Ready` receipt — error bounded by
    /// the one-way handshake latency). `None` for pre-v4 workers, whose
    /// exec timestamps are synthesized supervisor-side instead.
    pub clock_offset_us: Option<i64>,
    /// The named experiments this worker's registry advertised in `Ready`
    /// (v5+). `None` for pre-v5 workers; the supervisor routes only
    /// *unnamed* tasks to those. `Some(vec![])` is a v5 worker that
    /// registers no names — same routing, but declared rather than
    /// assumed.
    pub exps: Option<Vec<String>>,
}

struct PoolState {
    queue: VecDeque<Registration>,
    /// Set once the acceptor thread exits; leases then fail fast instead
    /// of waiting out their full deadline on a dead pool.
    closed: bool,
}

/// Innards shared between the pool handle and its acceptor thread. Kept
/// separate from [`WorkerPool`] so the acceptor never holds the public
/// handle — otherwise the handle's `Drop` (which stops the acceptor)
/// could never run.
struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
    registered: AtomicU64,
    rejected: AtomicU64,
}

/// A standing, authenticated pool of registered remote workers (see the
/// [module docs](self) for the lifecycle).
pub struct WorkerPool {
    endpoint: Endpoint,
    shared: Arc<PoolShared>,
    stop: Arc<AtomicBool>,
    acceptor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("endpoint", &self.endpoint.to_string())
            .field("registered", &self.registered_count())
            .field("rejected", &self.rejected_count())
            .finish()
    }
}

impl WorkerPool {
    /// Binds the transport and starts accepting worker registrations on a
    /// background thread. The returned handle is shared (`Arc`) because
    /// supervisor slots lease from it concurrently — and because keeping
    /// it across `Memento` runs is exactly how worker processes get
    /// reused.
    pub fn listen(
        transport: &Transport,
        opts: PoolOptions,
    ) -> Result<Arc<WorkerPool>, MementoError> {
        if matches!(transport, Transport::Tcp { .. }) && opts.token.is_none() {
            return Err(MementoError::config(
                "a TCP worker pool requires a shared auth token (anyone who can \
                 reach the port could otherwise register as a worker)",
            ));
        }
        let (listener, sock_dir) = transport
            .bind()
            .map_err(|e| MementoError::ipc(format!("bind {transport:?}: {e}")))?;
        let endpoint = listener.endpoint();
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            registered: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let handle = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("memento-pool-accept".into())
                .spawn(move || {
                    // The Unix socket's temp dir (if any) lives and dies
                    // with the acceptor.
                    let _sock_dir = sock_dir;
                    shared.accept_loop(listener, opts, stop);
                })
                .map_err(|e| MementoError::ipc(format!("spawn pool acceptor: {e}")))?
        };
        Ok(Arc::new(WorkerPool {
            endpoint,
            shared,
            stop,
            acceptor: Mutex::new(Some(handle)),
        }))
    }

    /// The address workers should connect to — with a `:0` bind request
    /// this carries the OS-assigned port, so it is what a `memento serve
    /// --connect` invocation (or [`crate::ipc::worker::serve_remote`])
    /// needs.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// Takes the next registered worker, waiting up to `timeout` for one
    /// to register. `None` means no worker became available (or the pool
    /// shut down) — callers treat that like a failed worker spawn.
    pub fn lease(&self, timeout: Duration) -> Option<Registration> {
        let deadline = Instant::now() + timeout;
        let mut state = self.shared.state.lock().unwrap();
        loop {
            if let Some(reg) = state.queue.pop_front() {
                return Some(reg);
            }
            if state.closed {
                return None;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return None;
            }
            let (st, _timeout) = self.shared.cv.wait_timeout(state, remaining).unwrap();
            state = st;
        }
    }

    /// Registered workers currently queued (not leased).
    pub fn available(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Total successful registrations over the pool's lifetime. A
    /// standing worker counts once per (re)connection, so this growing
    /// across runs is the pool-reuse story working.
    pub fn registered_count(&self) -> u64 {
        self.shared.registered.load(Ordering::SeqCst)
    }

    /// Registrations refused (bad token or protocol mismatch).
    pub fn rejected_count(&self) -> u64 {
        self.shared.rejected.load(Ordering::SeqCst)
    }

    /// Stops accepting registrations and drops every queued connection
    /// (their workers observe EOF-before-`Hello` and retry or give up per
    /// their own options). Called by `Drop`; idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.lock().unwrap().take() {
            let _ = h.join();
        }
        let mut state = self.shared.state.lock().unwrap();
        state.closed = true;
        state.queue.clear();
        self.shared.cv.notify_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl PoolShared {
    fn accept_loop(
        self: Arc<Self>,
        listener: Box<dyn WireListener>,
        opts: PoolOptions,
        stop: Arc<AtomicBool>,
    ) {
        crate::ipc::transport::poll_accept(listener, &stop, |stream| {
            // Handshake each connection on its own short-lived thread: the
            // TCP listener is reachable by untrusted peers, and a silent
            // connection gets `handshake_timeout` to produce its `Ready` —
            // serializing that wait here would let one garbage connection
            // stall every legitimate registration behind it.
            let shared = Arc::clone(&self);
            let opts = opts.clone();
            let spawned = std::thread::Builder::new()
                .name("memento-pool-handshake".into())
                .spawn(move || shared.register(stream, &opts));
            drop(spawned); // spawn failure just drops the connection
        });
        let mut state = self.state.lock().unwrap();
        state.closed = true;
        state.queue.clear();
        self.cv.notify_all();
    }

    /// Handshakes one inbound connection: read `Ready`, verify protocol
    /// and token, queue it — or answer `Reject` and drop it.
    fn register(&self, stream: Box<dyn WireStream>, opts: &PoolOptions) {
        // The handshake must arrive promptly; a silent connection is
        // dropped rather than wedging the acceptor.
        let _ = stream.set_stream_read_timeout(Some(opts.handshake_timeout));
        let mut reader = stream;
        let ready = match read_frame(&mut reader) {
            Ok(Some(m)) => m,
            _ => return, // silent/garbled connection: drop without ceremony
        };
        let Msg::Ready { worker, pid, protocol, token, clock_us, exps, .. } = ready else {
            return;
        };
        let clock_offset_us =
            clock_us.map(|c| crate::obs::trace::monotonic_us() as i64 - c as i64);
        let refusal = if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&protocol) {
            Some(format!(
                "protocol mismatch: pool speaks v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION}, \
                 worker speaks v{protocol}"
            ))
        } else if let Some(required) = &opts.token {
            if token.as_deref() == Some(required.as_str()) {
                None
            } else {
                Some("auth token mismatch".to_string())
            }
        } else {
            None
        };
        if let Some(reason) = refusal {
            self.rejected.fetch_add(1, Ordering::SeqCst);
            eprintln!(
                "memento pool: rejected registration from {} (pid {pid}): {reason}",
                reader.peer_label()
            );
            let _ = write_frame(&mut reader, &Msg::Reject { reason });
            let _ = reader.shutdown_both();
            return;
        }
        // Authenticated: normalize the stream (no read deadline — a
        // queued worker may wait arbitrarily long for its lease) and park
        // it for the next lease.
        let _ = reader.set_stream_read_timeout(None);
        let mut state = self.state.lock().unwrap();
        if state.closed {
            // The pool shut down while this handshake thread was mid
            // flight; dropping the connection tells the worker to retry
            // elsewhere (EOF before Hello).
            return;
        }
        let member = self.registered.fetch_add(1, Ordering::SeqCst) + 1;
        state.queue.push_back(Registration {
            stream: reader,
            member,
            worker,
            pid,
            protocol,
            clock_offset_us,
            exps,
        });
        drop(state);
        self.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tcp_pool(token: &str) -> Arc<WorkerPool> {
        WorkerPool::listen(
            &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
            PoolOptions { token: Some(token.to_string()), ..PoolOptions::default() },
        )
        .unwrap()
    }

    fn send_ready(endpoint: &Endpoint, protocol: u64, token: Option<&str>) -> Box<dyn WireStream> {
        let mut stream = endpoint.connect().unwrap();
        write_frame(
            &mut stream,
            &Msg::Ready {
                worker: 9,
                pid: 1234,
                spawn: 0,
                protocol,
                token: token.map(|t| t.to_string()),
                clock_us: if protocol >= 4 { Some(1) } else { None },
                exps: if protocol >= 5 {
                    Some(vec!["echo".to_string()])
                } else {
                    None
                },
            },
        )
        .unwrap();
        stream
    }

    #[test]
    fn tcp_pool_requires_a_token() {
        let err = WorkerPool::listen(
            &Transport::Tcp { bind: "127.0.0.1:0".to_string() },
            PoolOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("token"), "{err}");
    }

    #[test]
    fn good_token_registers_and_leases() {
        let pool = tcp_pool("s3cret");
        let _stream = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let reg = pool.lease(Duration::from_secs(5)).expect("worker registers");
        assert_eq!(reg.worker, 9);
        assert_eq!(reg.pid, 1234);
        assert_eq!(reg.member, 1);
        assert_eq!(reg.protocol, PROTOCOL_VERSION);
        assert!(reg.clock_offset_us.is_some(), "v4 ready carries a clock sample");
        assert_eq!(
            reg.exps.as_deref(),
            Some(&["echo".to_string()][..]),
            "v5 ready carries the capability list"
        );
        assert_eq!(pool.registered_count(), 1);
        assert_eq!(pool.rejected_count(), 0);
    }

    #[test]
    fn v2_worker_still_registers() {
        // A JSON-only v2 worker is frame-compatible; the pool admits it
        // and records its version so the supervisor sticks to JSON.
        let pool = tcp_pool("s3cret");
        let _stream = send_ready(pool.endpoint(), MIN_PROTOCOL_VERSION, Some("s3cret"));
        let reg = pool.lease(Duration::from_secs(5)).expect("v2 worker registers");
        assert_eq!(reg.protocol, MIN_PROTOCOL_VERSION);
        assert_eq!(reg.clock_offset_us, None, "pre-v4 ready has no clock sample");
        assert_eq!(reg.exps, None, "pre-v5 ready has no capability list");
        assert_eq!(pool.rejected_count(), 0);
    }

    #[test]
    fn pre_v2_worker_is_rejected() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), 1, Some("s3cret"));
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        assert!(
            matches!(answer, Msg::Reject { ref reason } if reason.contains("protocol")),
            "{answer:?}"
        );
        assert_eq!(pool.rejected_count(), 1);
    }

    #[test]
    fn bad_token_is_rejected_with_a_reason() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("wrong"));
        // The worker hears an explicit Reject, not just a closed socket.
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        let reason = match answer {
            Msg::Reject { reason } => reason,
            other => panic!("expected Reject, got {other:?}"),
        };
        assert!(reason.contains("token"), "{reason}");
        // And the pool never offers it for lease.
        assert!(pool.lease(Duration::from_millis(100)).is_none());
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(pool.registered_count(), 0);
    }

    #[test]
    fn protocol_mismatch_is_rejected() {
        let pool = tcp_pool("s3cret");
        let mut stream = send_ready(pool.endpoint(), PROTOCOL_VERSION + 1, Some("s3cret"));
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let answer = read_frame(&mut stream).unwrap().unwrap();
        assert!(
            matches!(answer, Msg::Reject { ref reason } if reason.contains("protocol")),
            "{answer:?}"
        );
        assert_eq!(pool.rejected_count(), 1);
    }

    #[test]
    fn lease_times_out_on_an_empty_pool() {
        let pool = tcp_pool("s3cret");
        let started = Instant::now();
        assert!(pool.lease(Duration::from_millis(80)).is_none());
        assert!(started.elapsed() >= Duration::from_millis(80));
    }

    #[test]
    fn shutdown_fails_leases_fast() {
        let pool = tcp_pool("s3cret");
        pool.shutdown();
        let started = Instant::now();
        assert!(pool.lease(Duration::from_secs(30)).is_none());
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "a closed pool must not wait out the full lease deadline"
        );
    }

    #[test]
    fn registrations_queue_in_arrival_order() {
        let pool = tcp_pool("s3cret");
        let _a = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let first = pool.lease(Duration::from_secs(5)).unwrap();
        let _b = send_ready(pool.endpoint(), PROTOCOL_VERSION, Some("s3cret"));
        let second = pool.lease(Duration::from_secs(5)).unwrap();
        assert_eq!((first.member, second.member), (1, 2));
        assert_eq!(pool.registered_count(), 2);
    }
}
