//! Supervisor side of the process-isolated and distributed backends.
//!
//! The supervisor owns the run: it obtains worker connections — either by
//! **spawning** worker processes over a private Unix socket
//! ([`WorkerSource::Spawn`], the `--isolation process` tier) or by
//! **leasing** standing workers that registered with a TCP
//! [`WorkerPool`] ([`WorkerSource::Pool`], the `--isolation remote`
//! tier) — hands out **one attempt at a time** over the wire, and folds
//! the streamed outcomes back into the same
//! journal/metrics/progress/record pipeline the thread backend uses.
//!
//! # Crash semantics
//!
//! A worker that dies mid-task (segfault, abort, OOM-kill, `kill -9`,
//! dropped network link) is detected by connection EOF — or, for a
//! wedged-but-alive worker, by a heartbeat silence longer than the
//! heartbeat timeout, in which case the supervisor kills it. Either way
//! the in-flight attempt is journaled as `TaskFailed` (kind
//! [`FailureKind::Crash`]) and the task is requeued under the run's
//! [`RetryPolicy`] exactly as an in-process failure would be. What
//! replaces the worker depends on the source:
//!
//! - **Spawn**: the slot respawns a fresh process, up to `crash_budget`
//!   respawns per slot over the whole run.
//! - **Pool**: the slot leases the next registered worker. The crashed
//!   worker itself may reconnect and re-register (standing workers retry
//!   with backoff — see [`crate::ipc::worker::serve_remote`]), so the
//!   budget counts **consecutive** worker losses per slot and resets on
//!   every completed attempt: a mid-run drop that rejoins does not creep
//!   toward retirement, while a pool supplying only instantly-dying
//!   connections still retires the slot after `crash_budget + 1` losses
//!   in a row. A lease that times out with **nothing registered** counts
//!   the same way — but a timeout while every worker is *leased out*
//!   (concurrent runs sharing the pool) is contention, not failure: the
//!   slot returns its attempt and retries without consuming budget, so
//!   runs can never charge each other's borrows to their crash budgets.
//!
//! A slot that exhausts its budget retires; if **every** slot retires
//! with work still pending, the remaining tasks become failed outcomes
//! (never silently dropped), so a run always accounts for each spec
//! exactly once.
//!
//! # Task timeouts (distinct from crashes)
//!
//! With [`SupervisorOptions::task_timeout`] set, an attempt that is still
//! running when its wall-clock budget lapses is **stopped** — the spawned
//! worker is killed, a leased connection is dropped — journaled as
//! [`Event::TaskTimedOut`], and requeued under the same [`RetryPolicy`]
//! with kind [`FailureKind::Timeout`]. A timeout is the *task's* fault,
//! not the worker's: it never consumes crash budget, so a sweep with a
//! few runaway configurations cannot retire its slots. (A leased remote
//! worker keeps executing the runaway attempt until it finishes, then
//! notices the dead connection and re-registers; a spawned worker is
//! simply killed and respawned.)
//!
//! # Clean departures
//!
//! A worker that closes its connection deliberately announces it with a
//! `Goodbye` frame (standing workers do this when they hit their
//! per-connection task budget). A dispatch that crosses with a `Goodbye`
//! is re-queued without consuming a retry attempt or crash budget — the
//! worker guarantees it executes nothing sent after the frame. The
//! re-dispatch repeats the attempt's `TaskStarted` journal line (the
//! first one never ran); results stay exactly-once.
//!
//! # What workers never do
//!
//! Workers execute the experiment function and nothing else. The result
//! cache, checkpoint store, journal, and notifier live exclusively in the
//! supervisor process — which is why the process backend can open the
//! cache in single-writer mode ([`crate::coordinator::cache::ResultCache`]
//! `::exclusive`) and skip per-miss disk probes, and why a remote worker
//! machine needs no shared filesystem: results travel back over the wire.

use crate::coordinator::error::{FailureKind, MementoError, TaskFailure};
use crate::coordinator::journal::{Event, Journal};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::progress::ProgressState;
use crate::coordinator::results::{TaskOutcome, TaskStatus};
use crate::coordinator::retry::RetryPolicy;
use crate::coordinator::run::{EventSink, RunEvent};
use crate::coordinator::source::{DrainOnceSource, SpecFilter, SpecSource, ABORT_DRAIN_LIMIT};
use crate::coordinator::task::{TaskId, TaskSpec};
use crate::ipc::pool::{Lease, LeaseToken, WorkerPool};
use crate::ipc::proto::{
    read_frame, write_frame, write_frame_as, Msg, WireFormat, WireResult, PROTOCOL_VERSION,
};
use crate::ipc::transport::{bind_unix, WireListener, WireStream};
use crate::ipc::worker::{ENV_SOCKET, ENV_WORKER_ID, ENV_WORKER_SPAWN};
use crate::obs::snapshot::FleetStats;
use crate::obs::trace::{monotonic_us, SpanState, Tracer};
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Supervisor configuration.
#[derive(Debug, Clone)]
pub struct SupervisorOptions {
    /// Worker processes to run concurrently (spawn mode), or concurrent
    /// worker leases (pool mode).
    pub workers: usize,
    /// Worker-loss budget **per slot** before the slot retires. Spawn
    /// mode counts respawns over the whole run; pool mode counts
    /// *consecutive* losses without a completed attempt (see the module
    /// docs).
    pub crash_budget: u32,
    /// Retry policy applied to failed attempts, worker crashes, *and*
    /// task timeouts.
    pub retry: RetryPolicy,
    /// Stop dispatching after the first failed task.
    pub fail_fast: bool,
    /// Experiment version salt (must match the workers' task hashing).
    pub version: String,
    /// Base RNG seed forwarded to workers.
    pub run_seed: u64,
    /// Worker heartbeat interval.
    pub heartbeat: Duration,
    /// Silence longer than this kills the worker as hung. Must comfortably
    /// exceed `heartbeat`; heartbeats flow *during* task execution, so
    /// this does not bound task duration.
    pub heartbeat_timeout: Duration,
    /// Per-task wall-clock budget: an attempt still running after this
    /// long is stopped, journaled as a timeout, and requeued under
    /// `retry` — without consuming crash budget. `None` = unbounded (the
    /// default; heartbeats already distinguish slow from hung).
    pub task_timeout: Option<Duration>,
    /// Spawn → `Ready` handshake deadline per worker (spawn mode), and
    /// the per-acquisition lease deadline (pool mode).
    pub connect_timeout: Duration,
    /// Program to execute for workers. `None` = the current executable.
    /// Spawn mode only.
    pub worker_program: Option<PathBuf>,
    /// Argument vector for worker processes (spawn mode only). The
    /// default re-uses the current process's own arguments, which is
    /// correct for binaries that reach `Memento::run` again when
    /// re-executed (the run call notices the worker environment and
    /// serves tasks instead). Test binaries should pass a libtest filter
    /// selecting their worker-entry `#[test]`.
    pub worker_args: Vec<String>,
    /// Payload encoding for post-handshake frames toward v3+ workers
    /// (announced in `Hello`; pre-v3 registrants always get JSON
    /// regardless). [`WireFormat::Json`] is the `--wire json` debugging
    /// mode.
    pub wire: WireFormat,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        SupervisorOptions {
            workers: crate::util::pool::num_cpus(),
            crash_budget: 3,
            retry: RetryPolicy::none(),
            fail_fast: false,
            version: "v1".to_string(),
            run_seed: 0,
            heartbeat: Duration::from_millis(200),
            heartbeat_timeout: Duration::from_secs(10),
            task_timeout: None,
            connect_timeout: Duration::from_secs(20),
            worker_program: None,
            worker_args: std::env::args().skip(1).collect(),
            wire: WireFormat::default(),
        }
    }
}

/// Where the supervisor gets worker connections from.
pub enum WorkerSource {
    /// Spawn worker processes locally, connected over a private Unix
    /// socket in a fresh temp dir (the `--isolation process` tier).
    Spawn,
    /// Lease standing workers that registered with this pool (the
    /// distributed tier). The pool may be shared across runs — see
    /// [`crate::ipc::pool`].
    Pool(Arc<WorkerPool>),
}

/// Callbacks wiring supervisor events into the coordinator pipeline. All
/// optional; a bare supervisor still returns a correct report.
#[derive(Default)]
#[allow(clippy::type_complexity)]
pub struct SupervisorHooks {
    /// Append-only run journal (task lifecycle events).
    pub journal: Option<Arc<Journal>>,
    /// Shared metrics registry (attempt counters, timers).
    pub metrics: Option<Arc<RunMetrics>>,
    /// Live progress counters for the CLI progress line.
    pub progress: Option<Arc<ProgressState>>,
    /// Persist in-task partial progress (checkpoint `progress/` slot).
    pub save_progress: Option<Arc<dyn Fn(&TaskId, &Json) + Send + Sync>>,
    /// Load restored progress for a (re)dispatched attempt.
    pub load_progress: Option<Arc<dyn Fn(&TaskId) -> Option<Json> + Send + Sync>>,
    /// Record a terminal outcome (cache put / checkpoint / notification).
    pub record: Option<Arc<dyn Fn(&TaskOutcome) + Send + Sync>>,
    /// Live event channel: `TaskStarted` per dispatched attempt, worker
    /// `Progress` frames forwarded as `TaskProgress`, crash/hang kills as
    /// `WorkerCrashed`. Terminal outcomes flow through `record`.
    pub events: Option<EventSink>,
    /// Cooperative cancellation: once set, nothing new is dispatched,
    /// pending retries are skipped, busy workers are asked to shut down
    /// and then stopped (their in-flight attempt is journaled as
    /// interrupted and accounted as skipped), and the lazy source is not
    /// consumed further — cancel latency is bounded by roughly one
    /// heartbeat, not one attempt.
    pub cancel: Option<Arc<std::sync::atomic::AtomicBool>>,
    /// The planner's restore stage, run on the dispatching slot's thread
    /// **outside** the source mutex (see
    /// [`crate::coordinator::source::DrainOnceSource`]): `None` means the
    /// spec was restored from cache/checkpoint and delivered out of band.
    pub restore_filter: Option<SpecFilter>,
    /// Fires exactly once, when the lazy spec source is exhausted and all
    /// pulled specs have cleared the restore filter.
    pub on_source_drained: Option<Box<dyn FnOnce() + Send + Sync>>,
    /// Span tracer for per-attempt timelines (`--trace-dir`). Slots record
    /// queued/dispatched transitions; worker-side exec timestamps from v4
    /// `Outcome` frames are mapped through the connection's clock offset
    /// (synthesized from `duration_secs` for older peers).
    pub tracer: Option<Arc<Tracer>>,
    /// Live per-worker stats (completions, heartbeat age, crash budget)
    /// feeding periodic telemetry snapshots.
    pub fleet: Option<Arc<FleetStats>>,
}

/// What happened across the whole process-backed run. Terminal outcomes
/// are **streamed** through [`SupervisorHooks::record`] as they complete
/// and are not re-accumulated here — on a huge lazy matrix the supervisor
/// must not hold a second copy of every outcome.
#[derive(Debug)]
pub struct ProcessReport {
    /// Terminal outcomes delivered to the `record` hook.
    pub completed: usize,
    /// Specs abandoned by a fail-fast abort or a cancel.
    pub skipped: Vec<TaskSpec>,
    /// True when fail-fast stopped the run early.
    pub aborted: bool,
    /// True when the cancel flag stopped the run early.
    pub cancelled: bool,
    /// True when an abort/retirement drain hit
    /// [`ABORT_DRAIN_LIMIT`] before exhausting the lazy source:
    /// `skipped`/failed-orphan accounting is then a lower bound.
    pub drain_truncated: bool,
    /// Worker deaths observed (crashes + hang-kills + failed
    /// spawns/leases).
    pub crashes: u32,
    /// Replacement workers spawned after a crash (spawn mode).
    pub respawns: u32,
    /// Attempts stopped for exceeding the per-task wall-clock budget.
    pub timeouts: u32,
}

/// One queued attempt. `index` is the task's position in the pulled-task
/// table (also the wire `Task.index` handle), not the spec's expansion
/// index.
#[derive(Debug, Clone, Copy)]
struct Attempt {
    index: usize,
    attempt: u32,
    /// Retry backoff: not dispatchable before this instant.
    ready_at: Option<Instant>,
    /// Capability re-routes so far for *this* attempt number: bumped when
    /// a worker answers `Unsupported` and the dispatch is returned to the
    /// queue without consuming the attempt. One re-route is allowed; a
    /// second mismatch fails the task with
    /// [`FailureKind::UnknownExperiment`] instead of ping-ponging.
    deferrals: u32,
}

/// Bound on incompatible *fresh* pulls one `next_task` search parks in
/// the pending queue before giving up and waiting: keeps a slot whose
/// worker serves none of the upcoming specs from eagerly enumerating the
/// whole lazy source looking for one it can run.
const MAX_DEFERRED_PULLS: usize = 16;

/// What a slot's current worker can serve, for capability-aware dispatch.
#[derive(Clone, Copy)]
enum SlotCaps<'a> {
    /// No connection yet: the slot will acquire a fresh worker before
    /// dispatching, so it is treated as able to serve anything. (Pool
    /// leases are FIFO, so the worker that actually arrives may still
    /// turn out incapable — the dispatch is then re-routed before the
    /// frame is written; see `slot_loop`.)
    Acquiring,
    /// A held connection's advertised capability list. `None` is a
    /// pre-v5 worker: it can be sent *unnamed* tasks only.
    Has(Option<&'a [String]>),
}

impl SlotCaps<'_> {
    /// Whether a task targeting `exp` (`None` = unnamed) may be
    /// dispatched under these capabilities.
    fn can_serve(&self, exp: Option<&str>) -> bool {
        match exp {
            None => true,
            Some(name) => match self {
                SlotCaps::Acquiring => true,
                SlotCaps::Has(None) => false,
                SlotCaps::Has(Some(list)) => list.iter().any(|n| n == name),
            },
        }
    }
}

/// A slot's entry on the shared capability board (owned mirror of the
/// [`SlotCaps`] the slot itself dispatches under), used by
/// `fail_unservable` to detect tasks no live worker can run.
#[derive(Clone)]
enum CapEntry {
    /// Between workers — may acquire a worker with any capabilities.
    Acquiring,
    /// Holding a connection that advertised this list (`None` = pre-v5).
    Has(Option<Vec<String>>),
}

struct Queue {
    /// Retry attempts waiting to be (re)dispatched. Fresh work is pulled
    /// from the lazy source instead of being queued here.
    pending: VecDeque<Attempt>,
    in_flight: usize,
    completed: usize,
    skipped: Vec<TaskSpec>,
    abort: bool,
    live_slots: usize,
}

enum Next {
    Run(Attempt),
    Wait(Duration),
    Done,
}

/// One pulled spec plus its precomputed id.
struct PulledTask {
    spec: TaskSpec,
    id: TaskId,
}

/// Where this run's worker connections come from, as held by the shared
/// state (the spawn socket path, or the lease pool).
enum Mode {
    Spawn { socket_path: PathBuf },
    Pool(Arc<WorkerPool>),
}

struct Shared {
    /// This run's pool lease ticket (pool mode; 0 in spawn mode). One
    /// ticket per run, shared by all its slots: the pool round-robins
    /// grants across tickets, so concurrent runs sharing a pool divide
    /// the worker supply fairly instead of racing FIFO.
    ticket: u64,
    /// The lazy spec stream — pulled one task per dispatch, never
    /// materialized. The exhaustion latch, fire-once completion hook,
    /// restore filter, and bounded abort drain all live inside
    /// [`DrainOnceSource`], shared with the thread scheduler.
    source: DrainOnceSource,
    /// Every spec pulled so far (grows with dispatch, not with the raw
    /// matrix size). Leaf lock: never acquire another lock while held.
    tasks: Mutex<Vec<PulledTask>>,
    settings: BTreeMap<String, Json>,
    opts: SupervisorOptions,
    hooks: SupervisorHooks,
    mode: Mode,
    q: Mutex<Queue>,
    cv: Condvar,
    /// Per-slot capability board (`None` = retired slot). Locked on its
    /// own, never while holding `q` or `tasks`.
    caps: Mutex<Vec<Option<CapEntry>>>,
    crashes: AtomicU32,
    respawns: AtomicU32,
    timeouts: AtomicU32,
    /// Set when a post-abort/retirement drain gave up before exhausting
    /// the source (see [`ABORT_DRAIN_LIMIT`]). The once-per-run latch for
    /// the abort drain itself lives inside [`DrainOnceSource`].
    drain_truncated: AtomicBool,
}

/// What the spawn-mode acceptor routes to a slot: the handshaken stream,
/// the Ready frame's spawn generation, the worker's declared protocol,
/// the estimated worker-clock offset (`None` for pre-v4 workers), and
/// the advertised experiment capabilities (`None` for pre-v5 workers).
type RoutedConn = (Box<dyn WireStream>, u64, u64, Option<i64>, Option<Vec<String>>);

/// A live worker: the connection halves, plus the child process handle
/// when this supervisor spawned it (`None` for leased pool workers —
/// their process belongs to another machine or supervisor-of-one).
struct Conn {
    child: Option<Child>,
    reader: Box<dyn WireStream>,
    writer: Box<dyn WireStream>,
    /// Negotiated payload format for frames written to this worker:
    /// [`SupervisorOptions::wire`] when the worker declared v3+ in its
    /// `Ready`, otherwise JSON. Reads auto-detect and need no format.
    wire: WireFormat,
    /// Estimated offset from the worker's monotonic clock to ours
    /// (supervisor clock at `Ready` receipt minus the frame's `clock_us`).
    /// `None` for pre-v4 workers — their exec spans are synthesized from
    /// the outcome's `duration_secs` instead.
    clock_offset_us: Option<i64>,
    /// Experiment names the worker's `Ready` advertised (v5+). `None` =
    /// pre-v5 worker, which may only be sent unnamed tasks — it would
    /// silently mis-hash (and mis-execute) a named one.
    exps: Option<Vec<String>>,
    /// Pool busy-accounting guard (pool mode; `None` for spawned
    /// workers). Held for the connection's lifetime so concurrent runs
    /// see this worker as leased, and released on drop — whether the
    /// connection ends cleanly, crashes, or is reaped.
    _lease: Option<LeaseToken>,
}

/// Runs every spec the lazy `source` yields across `opts.workers` worker
/// connections obtained from `workers`, and returns the collected report.
/// Blocks until all pulled specs are accounted for and (in spawn mode)
/// all children have exited. The source is consumed one task per dispatch
/// — never materialized.
pub fn run(
    source: SpecSource,
    settings: BTreeMap<String, Json>,
    opts: SupervisorOptions,
    mut hooks: SupervisorHooks,
    workers: WorkerSource,
) -> Result<ProcessReport, MementoError> {
    let slots = opts.workers.max(1);

    // Spawn mode binds a private Unix listener and routes incoming
    // connections to slots by worker id; pool mode needs neither (the
    // pool owns its own acceptor).
    let (mode, listener, sock_dir) = match workers {
        WorkerSource::Pool(pool) => (Mode::Pool(pool), None, None),
        WorkerSource::Spawn => {
            let dir = crate::util::fs::TempDir::new("ipc")
                .map_err(|e| MementoError::ipc(format!("create socket dir: {e}")))?;
            let socket_path = dir.join("supervisor.sock");
            let listener = bind_unix(&socket_path)
                .map_err(|e| MementoError::ipc(format!("bind {}: {e}", socket_path.display())))?;
            (
                Mode::Spawn { socket_path },
                Some(Box::new(listener) as Box<dyn WireListener>),
                Some(dir),
            )
        }
    };

    let drained_hook = hooks.on_source_drained.take();
    let restore_filter = hooks.restore_filter.take();
    // One lease ticket per run: the pool's round-robin grant policy keys
    // on it, so every slot of this run leases under the same identity.
    let ticket = match &mode {
        Mode::Pool(pool) => pool.ticket(),
        Mode::Spawn { .. } => 0,
    };
    let shared = Arc::new(Shared {
        ticket,
        source: DrainOnceSource::new(source, restore_filter, drained_hook),
        tasks: Mutex::new(Vec::new()),
        settings,
        opts,
        hooks,
        mode,
        q: Mutex::new(Queue {
            pending: VecDeque::new(),
            in_flight: 0,
            completed: 0,
            skipped: Vec::new(),
            abort: false,
            live_slots: slots,
        }),
        cv: Condvar::new(),
        caps: Mutex::new(vec![Some(CapEntry::Acquiring); slots]),
        crashes: AtomicU32::new(0),
        respawns: AtomicU32::new(0),
        timeouts: AtomicU32::new(0),
        drain_truncated: AtomicBool::new(false),
    });

    // Spawn-mode acceptor: routes each incoming connection to its slot by
    // the worker id in the Ready handshake (respawns make "arrival order"
    // unreliable), tagged with the handshake's spawn generation so a slot
    // can discard connections from incarnations it has already given up
    // on.
    let mut slot_rxs: Vec<Option<Receiver<RoutedConn>>> = Vec::new();
    let accept_stop = Arc::new(AtomicBool::new(false));
    let mut acceptor = None;
    match listener {
        None => slot_rxs.resize_with(slots, || None),
        Some(listener) => {
            let mut routes: Vec<Sender<RoutedConn>> = Vec::with_capacity(slots);
            for _ in 0..slots {
                let (tx, rx) = mpsc::channel();
                routes.push(tx);
                slot_rxs.push(Some(rx));
            }
            let stop = Arc::clone(&accept_stop);
            acceptor = Some(
                std::thread::Builder::new()
                    .name("memento-ipc-accept".into())
                    .spawn(move || accept_loop(listener, routes, stop))
                    .map_err(|e| MementoError::ipc(format!("spawn acceptor: {e}")))?,
            );
        }
    }

    let slot_handles: Vec<_> = slot_rxs
        .into_iter()
        .enumerate()
        .map(|(slot, rx)| {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("memento-ipc-slot-{slot}"))
                .spawn(move || slot_loop(&sh, slot, rx))
                .expect("spawn supervisor slot thread")
        })
        .collect();
    for s in slot_handles {
        let _ = s.join();
    }
    accept_stop.store(true, Ordering::SeqCst);
    if let Some(a) = acceptor {
        let _ = a.join();
    }
    drop(sock_dir);

    // All slot threads are joined: the queue is ours, no copies needed.
    let mut q = shared.q.lock().unwrap();
    let completed = q.completed;
    let mut skipped: Vec<TaskSpec> = std::mem::take(&mut q.skipped);
    let aborted = q.abort;
    drop(q);
    skipped.sort_by_key(|s| s.index);

    let crashes = shared.crashes.load(Ordering::SeqCst);
    let respawns = shared.respawns.load(Ordering::SeqCst);
    let timeouts = shared.timeouts.load(Ordering::SeqCst);
    let cancelled = shared.cancelled();
    let drain_truncated = shared.drain_truncated.load(Ordering::SeqCst);
    if let Some(m) = &shared.hooks.metrics {
        m.tasks_skipped.add(skipped.len() as u64);
    }
    // Exactly-once accounting over everything actually pulled (skipped may
    // exceed the remainder: an aborted run also drains the untouched rest
    // of the source — see `drain_source_as_skipped`).
    debug_assert!(
        completed + skipped.len() >= shared.pulled_count(),
        "every pulled spec accounted for"
    );
    Ok(ProcessReport {
        completed,
        skipped,
        aborted,
        cancelled,
        drain_truncated,
        crashes,
        respawns,
        timeouts,
    })
}

// ---- acceptor (spawn mode) ----------------------------------------------

fn accept_loop(
    listener: Box<dyn WireListener>,
    routes: Vec<Sender<RoutedConn>>,
    stop: Arc<AtomicBool>,
) {
    crate::ipc::transport::poll_accept(listener, &stop, |stream| {
        // The handshake must arrive promptly; a silent connection is
        // dropped rather than wedging the acceptor. Reading it inline is
        // fine here — only this supervisor's own spawned children can
        // reach the private Unix socket (unlike the worker pool's TCP
        // listener, which handshakes untrusted peers off-thread).
        let _ = stream.set_stream_read_timeout(Some(Duration::from_secs(5)));
        let mut reader = stream;
        match read_frame(&mut reader) {
            Ok(Some(Msg::Ready { worker, spawn, protocol, clock_us, exps, .. })) => {
                // Offset sampled at receipt: error is bounded by the
                // handshake's one-way latency (a local socket, so ~µs).
                let offset = clock_us.map(|c| monotonic_us() as i64 - c as i64);
                if let Some(tx) = routes.get(worker as usize) {
                    let _ = tx.send((reader, spawn, protocol, offset, exps));
                }
            }
            _ => drop(reader),
        }
    });
}

// ---- slot state machine -------------------------------------------------

fn slot_loop(sh: &Shared, slot: usize, rx: Option<Receiver<RoutedConn>>) {
    let mut conn: Option<Conn> = None;
    let mut crashes_used: u32 = 0;
    let pooled = matches!(sh.mode, Mode::Pool(_));
    // Bumped on every spawn; the worker echoes it in Ready, and
    // spawn_worker discards routed connections from older generations.
    let mut spawn_seq: u64 = 0;
    sh.fleet_budget(slot, crashes_used);
    loop {
        let own = match &conn {
            None => SlotCaps::Acquiring,
            Some(c) => SlotCaps::Has(c.exps.as_deref()),
        };
        let att = match sh.next_task(own) {
            Next::Done => break,
            Next::Wait(d) => {
                sh.wait_for_work(d);
                continue;
            }
            Next::Run(att) => att,
        };
        // Queued = admitted for dispatch; the gap to Dispatched is worker
        // acquisition (spawn/lease) plus the write itself.
        sh.trace_span(att, SpanState::Queued, None, true);
        if conn.is_none() {
            if crashes_used > sh.opts.crash_budget {
                sh.give_back(att);
                sh.retire_slot(slot, crashes_used);
                return;
            }
            spawn_seq += 1;
            let acquired = match &sh.mode {
                Mode::Spawn { .. } => {
                    let rx = rx.as_ref().expect("spawn mode has a route");
                    spawn_worker(sh, slot, rx, spawn_seq, crashes_used > 0)
                        .map_err(AcquireFail::Failed)
                }
                Mode::Pool(pool) => lease_worker(sh, pool),
            };
            match acquired {
                Ok(c) => {
                    sh.set_caps(slot, CapEntry::Has(c.exps.clone()));
                    conn = Some(c);
                }
                Err(AcquireFail::Contended) => {
                    // Every registered worker is leased out right now —
                    // by this run's other slots or by a concurrent run
                    // sharing the pool. That is contention, not a supply
                    // failure: return the attempt unconsumed and retry,
                    // charging nothing to this slot's crash budget (a
                    // neighbor's borrow must never retire our slot).
                    sh.give_back(att);
                    continue;
                }
                Err(AcquireFail::Failed(e)) => {
                    crashes_used += 1;
                    sh.fleet_budget(slot, crashes_used);
                    sh.crashes.fetch_add(1, Ordering::SeqCst);
                    eprintln!("memento supervisor: slot {slot} worker acquisition failed: {e}");
                    sh.emit(RunEvent::WorkerCrashed {
                        slot,
                        message: format!("worker acquisition failed: {e}"),
                    });
                    sh.give_back(att);
                    continue;
                }
            }
        }
        // The attempt may have been admitted while this slot was still
        // acquiring (wildcard capabilities); the worker that actually
        // arrived can be narrower — pool leases are FIFO, not matched.
        // Return the attempt unconsumed rather than dispatch a named
        // task the worker would refuse (or, pre-v5, silently mis-hash);
        // the next search dispatches under the real capability list.
        {
            let held = SlotCaps::Has(conn.as_ref().unwrap().exps.as_deref());
            if !held.can_serve(sh.task_exp(att.index).as_deref()) {
                sh.give_back(att);
                continue;
            }
        }
        match serve_attempt(sh, slot, conn.as_mut().unwrap(), att) {
            Serve::Completed => {
                if pooled {
                    // Pool budgets count *consecutive* losses: a completed
                    // attempt is proof the supply works again.
                    crashes_used = 0;
                    sh.fleet_budget(slot, crashes_used);
                }
            }
            Serve::NotDelivered => {
                // The Task frame never left this process: the worker died
                // while idle. Reap and replace, but return the attempt
                // unconsumed — the task was never touched.
                let mut dead = conn.take().unwrap();
                sh.set_caps(slot, CapEntry::Acquiring);
                let status = reap(&mut dead);
                crashes_used += 1;
                sh.fleet_budget(slot, crashes_used);
                sh.crashes.fetch_add(1, Ordering::SeqCst);
                sh.emit(RunEvent::WorkerCrashed {
                    slot,
                    message: format!("worker died while idle ({status})"),
                });
                sh.give_back(att);
            }
            Serve::Departed => {
                // Clean Goodbye: the worker left voluntarily (rolling
                // restart / per-connection budget) and guarantees the
                // crossed dispatch never ran. Replace the connection and
                // re-dispatch — no crash metric, no budget, no retry
                // attempt consumed.
                drop(conn.take());
                sh.set_caps(slot, CapEntry::Acquiring);
                sh.give_back(att);
            }
            Serve::Crashed => {
                // Worker died (or desynced) after taking the task: this
                // attempt is consumed and goes through the retry policy.
                let mut dead = conn.take().unwrap();
                sh.set_caps(slot, CapEntry::Acquiring);
                let status = reap(&mut dead);
                crashes_used += 1;
                sh.fleet_budget(slot, crashes_used);
                sh.crashes.fetch_add(1, Ordering::SeqCst);
                sh.emit(RunEvent::WorkerCrashed {
                    slot,
                    message: format!("worker process died mid-task ({status})"),
                });
                sh.attempt_failed(
                    att,
                    FailureKind::Crash,
                    format!("worker process died mid-task ({status})"),
                    0.0,
                );
            }
            Serve::TimedOut => {
                // The attempt outlived its wall-clock budget. Stop the
                // worker (kill a spawned child; drop a leased connection
                // — its standing worker re-registers once the runaway
                // task lets go), journal a timeout, and requeue under the
                // retry policy. Deliberate stops are the *task's* fault:
                // no crash budget is consumed.
                let mut dead = conn.take().unwrap();
                sh.set_caps(slot, CapEntry::Acquiring);
                let status = reap(&mut dead);
                sh.timeouts.fetch_add(1, Ordering::SeqCst);
                let budget = sh.opts.task_timeout.unwrap_or_default();
                sh.emit(RunEvent::WorkerCrashed {
                    slot,
                    message: format!(
                        "task exceeded its {budget:?} wall-clock budget; worker stopped ({status})"
                    ),
                });
                sh.attempt_timed_out(att, budget);
            }
            Serve::Interrupted => {
                // Cancel mid-attempt. The worker reads frames only between
                // attempts, so Shutdown alone cannot interrupt it: send it
                // anyway (a racing attempt that finishes inside the grace
                // window lets the worker exit cleanly), give the process
                // one heartbeat of grace, then stop it. The interruption
                // is journaled and the spec accounted as skipped — cancel
                // latency is bounded by heartbeats, not by the attempt's
                // duration. Deliberate stops don't consume crash budget.
                let mut dead = conn.take().unwrap();
                sh.set_caps(slot, CapEntry::Acquiring);
                let _ = write_frame_as(&mut dead.writer, &Msg::Shutdown, dead.wire);
                let deadline = Instant::now() + sh.opts.heartbeat;
                while Instant::now() < deadline {
                    match &mut dead.child {
                        Some(child) => {
                            if matches!(child.try_wait(), Ok(Some(_))) {
                                break;
                            }
                        }
                        None => break, // leased: nothing local to wait for
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                let status = reap(&mut dead);
                sh.interrupt_attempt(
                    att,
                    format!("interrupted: run cancelled mid-attempt; worker stopped ({status})"),
                );
            }
        }
    }
    if let Some(mut c) = conn {
        let _ = write_frame_as(&mut c.writer, &Msg::Shutdown, c.wire);
        // Close our read side before reaping: if the worker is blocked
        // writing into a full (unread) socket buffer, this fails its
        // write with EPIPE instead of letting `wait()` hang on a worker
        // that can never finish shutting down. Our buffered Shutdown
        // frame is still delivered first. (A leased standing worker takes
        // the Shutdown as end-of-run and re-registers with its pool.)
        let _ = c.reader.shutdown_read();
        if let Some(mut child) = c.child {
            let _ = child.wait();
        }
    }
    sh.retire_slot(slot, crashes_used);
}

/// How one dispatch attempt ended, from the slot's perspective.
enum Serve {
    /// An `Outcome` frame came back (success or contained failure).
    Completed,
    /// The `Task` frame could not even be written: the worker was already
    /// dead and the task provably never reached it.
    NotDelivered,
    /// The worker announced a clean departure (`Goodbye`) that crossed
    /// with the dispatch; the task provably never ran.
    Departed,
    /// The worker died (EOF/timeout/desync) after taking the task.
    Crashed,
    /// The attempt exceeded [`SupervisorOptions::task_timeout`].
    TimedOut,
    /// `Run::cancel` arrived while the attempt was executing: the slot
    /// stops the worker instead of waiting for the attempt to finish.
    Interrupted,
}

/// Dispatches one attempt and pumps frames until its outcome.
fn serve_attempt(sh: &Shared, slot: usize, conn: &mut Conn, att: Attempt) -> Serve {
    let (spec, id) = sh.task(att.index);
    let restored = sh
        .hooks
        .load_progress
        .as_ref()
        .and_then(|load| load(&id));

    let task = Msg::Task {
        index: att.index as u64,
        attempt: att.attempt as u64,
        params: spec.params.clone(),
        restored,
        // Named tasks carry their target and its registered version so
        // the worker salts the id exactly as the supervisor did.
        // Capability routing keeps named tasks away from pre-v5 workers,
        // which would ignore these keys.
        exp: spec.exp.as_ref().map(|e| e.name.clone()),
        exp_version: spec.exp.as_ref().map(|e| e.version.clone()),
    };
    let sent_at = Instant::now();
    // A previous attempt's deadline handling may have shortened the read
    // timeout; restore the heartbeat-silence baseline first.
    if sh.opts.task_timeout.is_some() {
        let _ = conn
            .reader
            .set_stream_read_timeout(Some(sh.opts.heartbeat_timeout));
    }
    if write_frame_as(&mut conn.writer, &task, conn.wire).is_err() {
        return Serve::NotDelivered;
    }
    // Journaled only after the frame was accepted for delivery: an
    // undelivered dispatch is requeued without consuming an attempt and
    // must not leave a started-but-never-finished entry in the log.
    if let Some(j) = &sh.hooks.journal {
        j.record(&Event::TaskStarted { id: id.clone(), attempt: att.attempt });
    }
    sh.emit(RunEvent::TaskStarted {
        index: spec.index,
        id: id.clone(),
        attempt: att.attempt,
    });
    sh.trace_span(att, SpanState::Dispatched, Some(slot as u64), false);
    let task_deadline = sh.opts.task_timeout.map(|d| sent_at + d);
    // Once a cancel is noticed, the attempt gets one heartbeat of grace to
    // deliver a racing `Outcome` (a result the worker already computed
    // must not be thrown away and re-executed on resume) before the slot
    // interrupts it.
    let mut cancel_deadline: Option<Instant> = None;
    loop {
        // Re-checked after every frame: a busy worker heartbeats at the
        // heartbeat interval, so a cancel (or a lapsed task budget) is
        // noticed within roughly one heartbeat instead of after the whole
        // attempt.
        if cancel_deadline.is_none() && sh.cancelled() {
            cancel_deadline = Some(Instant::now() + sh.opts.heartbeat);
        }
        let now = Instant::now();
        if let Some(deadline) = cancel_deadline {
            if now >= deadline {
                return Serve::Interrupted;
            }
        }
        if let Some(deadline) = task_deadline {
            if now >= deadline {
                return Serve::TimedOut;
            }
        }
        // Shorten reads to the nearest pending deadline so the wait is
        // bounded (never beyond the heartbeat-silence baseline).
        let nearest = match (cancel_deadline, task_deadline) {
            (Some(c), Some(t)) => Some(c.min(t)),
            (c, t) => c.or(t),
        };
        if let Some(deadline) = nearest {
            let remaining = deadline.saturating_duration_since(now);
            let _ = conn
                .reader
                .set_stream_read_timeout(Some(remaining.min(sh.opts.heartbeat_timeout)));
        }
        match read_frame(&mut conn.reader) {
            Ok(Some(Msg::Heartbeat { .. })) => {
                if let Some(f) = &sh.hooks.fleet {
                    f.heartbeat(slot as u64);
                }
                continue;
            }
            Ok(Some(Msg::Progress { index, value })) => {
                if let Some((spec_index, pid)) = sh.task_brief(index as usize) {
                    if let Some(save) = &sh.hooks.save_progress {
                        save(&pid, &value);
                    }
                    sh.emit(RunEvent::TaskProgress { index: spec_index, id: pid, value });
                }
            }
            Ok(Some(Msg::Goodbye)) => return Serve::Departed,
            Ok(Some(Msg::Outcome {
                index,
                attempt,
                duration_secs,
                exec_start_us,
                exec_end_us,
                result,
            })) => {
                if index as usize != att.index || attempt != att.attempt as u64 {
                    eprintln!(
                        "memento supervisor: slot {slot} answered task {index} (attempt \
                         {attempt}) while {} (attempt {}) was in flight — treating as crash",
                        att.index, att.attempt
                    );
                    return Serve::Crashed;
                }
                if let Some(m) = &sh.hooks.metrics {
                    // IPC + queueing overhead: round-trip minus execution.
                    let exec = Duration::from_secs_f64(duration_secs.max(0.0));
                    m.dispatch_overhead
                        .record(sent_at.elapsed().saturating_sub(exec));
                }
                sh.trace_exec(
                    att,
                    slot as u64,
                    conn.clock_offset_us,
                    exec_start_us,
                    exec_end_us,
                    duration_secs,
                );
                if let Some(f) = &sh.hooks.fleet {
                    f.task_completed(slot as u64);
                }
                match result {
                    WireResult::Ok { value } => sh.attempt_succeeded(att, value, duration_secs),
                    WireResult::Err { message, panicked } => sh.attempt_failed(
                        att,
                        if panicked { FailureKind::Panic } else { FailureKind::Error },
                        message,
                        duration_secs,
                    ),
                    // Capability mismatch: the worker refused the task
                    // without executing it. Not the worker's fault (the
                    // connection stays; no crash budget) and not a
                    // consumed attempt — re-route once to a capable
                    // worker, then fail explicitly rather than ping-pong.
                    WireResult::Unsupported { message } => sh.attempt_unsupported(att, message),
                }
                return Serve::Completed;
            }
            // EOF, heartbeat-timeout, unexpected frame, or stream error —
            // all terminal for this worker. During a cancel grace window
            // the shortened read timing out (or the worker exiting early)
            // is the expected interrupt path, not a crash; likewise a
            // lapsed task budget reads as a timeout, not a crash.
            Ok(Some(_)) | Ok(None) | Err(_) => {
                if cancel_deadline.is_some() {
                    return Serve::Interrupted;
                }
                if task_deadline.is_some_and(|d| Instant::now() >= d) {
                    return Serve::TimedOut;
                }
                return Serve::Crashed;
            }
        }
    }
}

/// Stops (idempotently) and reaps a dead worker, describing how it ended.
/// Leased pool workers have no local child process: their connection is
/// closed instead, and the remote process re-registers on its own.
fn reap(conn: &mut Conn) -> String {
    let _ = conn.reader.shutdown_both();
    match &mut conn.child {
        None => "remote connection closed".to_string(),
        Some(child) => {
            let _ = child.kill();
            match child.wait() {
                Ok(status) => status.to_string(),
                Err(e) => format!("unwaitable: {e}"),
            }
        }
    }
}

/// Why a slot failed to obtain a worker connection.
enum AcquireFail {
    /// Every registered pool worker is currently leased — by this run's
    /// other slots or by a concurrent run sharing the pool. Not a supply
    /// failure: the slot returns its attempt and retries without
    /// consuming crash budget. (Also the cancel path: a cancelled run
    /// stops waiting and lets `next_task` account the attempt.)
    Contended,
    /// A genuine acquisition failure: no worker registered within the
    /// window, the pool shut down, or a spawn failed. Charged to the
    /// slot's crash budget.
    Failed(MementoError),
}

/// How long one `lease_as` wait slice lasts inside [`lease_worker`]: the
/// bound on how stale the cancel check can get while a slot waits for a
/// worker grant.
const LEASE_SLICE: Duration = Duration::from_millis(250);

/// Leases the next pool worker granted to this run's ticket and completes
/// its run handshake (read deadline + `Hello`). Retries within the
/// connect-timeout window: a queue can hold stale registrations whose
/// worker died while parked, and those must not count as an acquisition
/// failure while live ones wait behind them. Waits in short slices so a
/// cancel (e.g. a daemon shutdown) is noticed promptly, and classifies an
/// expired window by the pool's busy signal: *contention* (workers exist,
/// all leased) is returned as [`AcquireFail::Contended`] so concurrent
/// runs sharing the pool never charge each other's borrows to a crash
/// budget.
fn lease_worker(sh: &Shared, pool: &Arc<WorkerPool>) -> Result<Conn, AcquireFail> {
    let deadline = Instant::now() + sh.opts.connect_timeout;
    let mut contended = false;
    loop {
        if sh.cancelled() {
            return Err(AcquireFail::Contended);
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            if contended {
                return Err(AcquireFail::Contended);
            }
            return Err(AcquireFail::Failed(MementoError::ipc(format!(
                "no remote worker registered with the pool at {} within {:?}",
                pool.endpoint(),
                sh.opts.connect_timeout
            ))));
        }
        let reg = match pool.lease_as(sh.ticket, remaining.min(LEASE_SLICE)) {
            Lease::Granted(reg) => reg,
            Lease::Closed => {
                return Err(AcquireFail::Failed(MementoError::ipc(format!(
                    "worker pool at {} shut down while a lease was pending",
                    pool.endpoint()
                ))));
            }
            Lease::TimedOut { busy } => {
                contended = busy;
                continue;
            }
        };
        if reg
            .stream
            .set_stream_read_timeout(Some(sh.opts.heartbeat_timeout))
            .is_err()
        {
            continue; // stale registration; try the next one
        }
        let Ok(mut writer) = reg.stream.try_clone_stream() else { continue };
        // Binary only toward workers that declared v3+ at registration,
        // and advertise the *negotiated* version in the Hello: a genuine
        // v2 worker hard-rejects any Hello whose protocol isn't 2, and v3
        // restricted to JSON is exactly v2.
        let wire = if reg.protocol >= 3 { sh.opts.wire } else { WireFormat::Json };
        let hello = Msg::Hello {
            protocol: reg.protocol.min(PROTOCOL_VERSION),
            version: sh.opts.version.clone(),
            run_seed: sh.opts.run_seed,
            settings: sh.settings.clone(),
            heartbeat_ms: sh.opts.heartbeat.as_millis().max(1) as u64,
            wire,
        };
        if write_frame(&mut writer, &hello).is_err() {
            continue; // worker died while parked in the queue
        }
        return Ok(Conn {
            child: None,
            reader: reg.stream,
            writer,
            wire,
            clock_offset_us: reg.clock_offset_us,
            exps: reg.exps,
            _lease: reg.lease,
        });
    }
}

fn spawn_worker(
    sh: &Shared,
    slot: usize,
    rx: &Receiver<RoutedConn>,
    spawn_seq: u64,
    is_respawn: bool,
) -> Result<Conn, MementoError> {
    let Mode::Spawn { socket_path } = &sh.mode else {
        return Err(MementoError::ipc("spawn_worker called without spawn mode"));
    };
    let program = match &sh.opts.worker_program {
        Some(p) => p.clone(),
        None => std::env::current_exe()
            .map_err(|e| MementoError::ipc(format!("current_exe: {e}")))?,
    };
    let mut child = Command::new(&program)
        .args(&sh.opts.worker_args)
        .env(ENV_SOCKET, socket_path)
        .env(ENV_WORKER_ID, slot.to_string())
        .env(ENV_WORKER_SPAWN, spawn_seq.to_string())
        .stdin(Stdio::null())
        .spawn()
        .map_err(|e| MementoError::ipc(format!("spawn {}: {e}", program.display())))?;
    if is_respawn {
        sh.respawns.fetch_add(1, Ordering::SeqCst);
    }

    // Accept only the connection whose Ready echoed *this* spawn's
    // generation: a previous incarnation that connected late (after its
    // slot already gave up on it) is discarded here instead of being
    // mistaken for the fresh worker.
    let deadline = Instant::now() + sh.opts.connect_timeout;
    let (stream, peer_protocol, clock_offset_us, exps) = loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            let _ = child.kill();
            let _ = child.wait();
            return Err(MementoError::ipc(format!(
                "worker slot {slot} did not connect within {:?}",
                sh.opts.connect_timeout
            )));
        }
        match rx.recv_timeout(remaining) {
            Ok((s, spawn, protocol, offset, exps)) if spawn == spawn_seq => {
                break (s, protocol, offset, exps)
            }
            Ok(_) => continue, // stale incarnation; drop its stream
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(MementoError::ipc(format!(
                    "worker slot {slot} did not connect within {:?}",
                    sh.opts.connect_timeout
                )));
            }
        }
    };
    stream
        .set_stream_read_timeout(Some(sh.opts.heartbeat_timeout))
        .map_err(|e| MementoError::ipc(format!("set read timeout: {e}")))?;
    let mut writer = stream
        .try_clone_stream()
        .map_err(|e| MementoError::ipc(format!("clone stream: {e}")))?;
    // Spawned workers are normally this very binary (v3), but a custom
    // `worker_program` may be older — honor its declared version, and
    // advertise the negotiated (minimum) version back: v2 workers
    // hard-reject a Hello that doesn't say v2.
    let wire = if peer_protocol >= 3 { sh.opts.wire } else { WireFormat::Json };
    let hello = Msg::Hello {
        protocol: peer_protocol.min(PROTOCOL_VERSION),
        version: sh.opts.version.clone(),
        run_seed: sh.opts.run_seed,
        settings: sh.settings.clone(),
        heartbeat_ms: sh.opts.heartbeat.as_millis().max(1) as u64,
        wire,
    };
    if let Err(e) = write_frame(&mut writer, &hello) {
        let _ = child.kill();
        let _ = child.wait();
        return Err(MementoError::ipc(format!("send hello: {e}")));
    }
    Ok(Conn {
        child: Some(child),
        reader: stream,
        writer,
        wire,
        clock_offset_us,
        exps,
        _lease: None,
    })
}

// ---- shared queue operations -------------------------------------------

impl Shared {
    fn cancelled(&self) -> bool {
        self.hooks
            .cancel
            .as_ref()
            .map(|c| c.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    fn emit(&self, event: RunEvent) {
        if let Some(s) = &self.hooks.events {
            s.emit(event);
        }
    }

    /// Records one span for a pulled attempt, translating the wire index
    /// to the spec's expansion index — the stable task identity every
    /// backend's spans share. `with_label` attaches the human-readable
    /// `k=v` parameter label (done once per attempt, on `Queued`).
    fn trace_span(&self, att: Attempt, state: SpanState, worker: Option<u64>, with_label: bool) {
        let Some(tracer) = &self.hooks.tracer else { return };
        let tasks = self.tasks.lock().unwrap();
        let Some(t) = tasks.get(att.index) else { return };
        let index = t.spec.index;
        let label = with_label.then(|| t.spec.label());
        drop(tasks);
        tracer.record(index, att.attempt, state, worker, label);
    }

    /// Records the exec window of a completed attempt: worker-reported
    /// timestamps mapped through the connection's clock offset when the
    /// peer is v4+, otherwise synthesized from `duration_secs` around the
    /// outcome's arrival — so pre-v4 workers still yield full timelines,
    /// just with dispatch latency folded into the exec span's position.
    fn trace_exec(
        &self,
        att: Attempt,
        slot: u64,
        clock_offset_us: Option<i64>,
        exec_start_us: Option<u64>,
        exec_end_us: Option<u64>,
        duration_secs: f64,
    ) {
        let Some(tracer) = &self.hooks.tracer else { return };
        let Some((spec_index, _)) = self.task_brief(att.index) else { return };
        let (start, end) = match (clock_offset_us, exec_start_us, exec_end_us) {
            (Some(off), Some(s), Some(e)) => {
                ((s as i64 + off).max(0) as u64, (e as i64 + off).max(0) as u64)
            }
            _ => {
                let end = monotonic_us();
                let start = end.saturating_sub((duration_secs.max(0.0) * 1e6) as u64);
                (start, end)
            }
        };
        tracer.record_mono(spec_index, att.attempt, SpanState::ExecStart, start, Some(slot));
        tracer.record_mono(spec_index, att.attempt, SpanState::ExecEnd, end, Some(slot));
    }

    /// Publishes a slot's remaining crash budget to the fleet stats.
    fn fleet_budget(&self, slot: usize, crashes_used: u32) {
        if let Some(f) = &self.hooks.fleet {
            let remaining = self.opts.crash_budget.saturating_sub(crashes_used);
            f.set_crash_budget_remaining(slot as u64, remaining);
        }
    }

    /// Spec + id of a pulled task (panics on an unknown index — internal
    /// dispatch handles are always valid).
    fn task(&self, index: usize) -> (TaskSpec, TaskId) {
        let tasks = self.tasks.lock().unwrap();
        let t = &tasks[index];
        (t.spec.clone(), t.id.clone())
    }

    /// Expansion index + id of a pulled task without cloning the spec —
    /// tolerant of garbage indices from a misbehaving worker frame.
    fn task_brief(&self, index: usize) -> Option<(usize, TaskId)> {
        let tasks = self.tasks.lock().unwrap();
        tasks.get(index).map(|t| (t.spec.index, t.id.clone()))
    }

    /// The experiment name a pulled task targets (`None` = unnamed).
    fn task_exp(&self, index: usize) -> Option<String> {
        let tasks = self.tasks.lock().unwrap();
        tasks
            .get(index)
            .and_then(|t| t.spec.exp.as_ref().map(|e| e.name.clone()))
    }

    /// Publishes a slot's current worker capabilities to the board.
    fn set_caps(&self, slot: usize, entry: CapEntry) {
        self.caps.lock().unwrap()[slot] = Some(entry);
    }

    fn pulled_count(&self) -> usize {
        self.tasks.lock().unwrap().len()
    }

    /// Pulls one fresh pending spec from the lazy source (restore
    /// filtering happens inside [`DrainOnceSource::pop`], on this slot's
    /// thread, outside the source mutex), registering it in the
    /// pulled-task table.
    fn pull_fresh(&self) -> Option<usize> {
        let spec = self.source.pop()?;
        let id = spec.id(&self.opts.version);
        let mut tasks = self.tasks.lock().unwrap();
        tasks.push(PulledTask { spec, id });
        Some(tasks.len() - 1)
    }

    /// Frees a terminal task's (potentially large) parameter payload. The
    /// slot keeps its id and expansion index so a late frame from a
    /// desynced worker still resolves, but supervisor memory no longer
    /// grows with the full parameter payload of every completed task.
    fn release_task(&self, index: usize) {
        if let Some(t) = self.tasks.lock().unwrap().get_mut(index) {
            t.spec.params = Vec::new();
        }
    }

    /// After a fail-fast abort: account for the specs the run never
    /// reached by draining the rest of the source as skips — bounded by
    /// [`ABORT_DRAIN_LIMIT`] so the abort returns promptly on a huge
    /// matrix (the un-enumerated remainder is flagged as truncated), and
    /// once-only per run (the latch lives in [`DrainOnceSource`], so the
    /// slots re-entering `next_task` cannot multiply the bound). Cancel
    /// stops the drain immediately; restorable specs still restore.
    fn drain_source_as_skipped(&self) {
        let report = self.source.drain(
            ABORT_DRAIN_LIMIT,
            &mut |spec| {
                if let Some(p) = &self.hooks.progress {
                    p.mark_skipped();
                }
                self.q.lock().unwrap().skipped.push(spec);
            },
            &|| self.cancelled(),
        );
        if report.truncated {
            self.drain_truncated.store(true, Ordering::SeqCst);
        }
    }

    fn next_task(&self, own: SlotCaps<'_>) -> Next {
        let stopping = {
            let mut q = self.q.lock().unwrap();
            let stop = q.abort || self.cancelled();
            if stop && !q.pending.is_empty() {
                let drained: Vec<Attempt> = q.pending.drain(..).collect();
                {
                    let tasks = self.tasks.lock().unwrap();
                    for att in &drained {
                        q.skipped.push(tasks[att.index].spec.clone());
                    }
                }
                if let Some(p) = &self.hooks.progress {
                    for _ in 0..drained.len() {
                        p.mark_skipped();
                    }
                }
                self.cv.notify_all();
            }
            if !stop {
                // Retry attempts first — they are older work. Only
                // attempts this slot's worker can actually serve are
                // eligible; incompatible ones stay queued for a capable
                // slot (`fail_unservable` catches the case where none
                // exists).
                let now = Instant::now();
                let ready = {
                    let tasks = self.tasks.lock().unwrap();
                    q.pending.iter().position(|a| {
                        a.ready_at.map(|t| t <= now).unwrap_or(true)
                            && own.can_serve(
                                tasks[a.index].spec.exp.as_ref().map(|e| e.name.as_str()),
                            )
                    })
                };
                if let Some(pos) = ready {
                    let att = q.pending.remove(pos).unwrap();
                    q.in_flight += 1;
                    return Next::Run(att);
                }
            }
            stop
        };

        if !stopping {
            // Fresh work, pulled lazily from the expansion stream. A pull
            // this slot's worker cannot serve is parked in the pending
            // queue for a capable slot — bounded per search so one narrow
            // worker cannot eagerly enumerate the whole source.
            let mut deferred = 0usize;
            while deferred < MAX_DEFERRED_PULLS {
                let Some(index) = self.pull_fresh() else { break };
                let servable_here = {
                    let tasks = self.tasks.lock().unwrap();
                    own.can_serve(tasks[index].spec.exp.as_ref().map(|e| e.name.as_str()))
                };
                if servable_here {
                    let mut q = self.q.lock().unwrap();
                    q.in_flight += 1;
                    return Next::Run(Attempt {
                        index,
                        attempt: 1,
                        ready_at: None,
                        deferrals: 0,
                    });
                }
                deferred += 1;
                let mut q = self.q.lock().unwrap();
                q.pending.push_back(Attempt {
                    index,
                    attempt: 1,
                    ready_at: None,
                    deferrals: 0,
                });
                drop(q);
                self.cv.notify_all();
            }
        } else if !self.cancelled() && self.q.lock().unwrap().abort {
            // Idempotent: DrainOnceSource latches the drain, so waiting
            // slots re-entering here cannot multiply the bound.
            self.drain_source_as_skipped();
        }

        // Before settling into a wait, fail any queued work no live
        // worker registers — otherwise a named task whose only capable
        // worker departed would sit in `pending` forever.
        if !stopping {
            self.fail_unservable();
        }

        let q = self.q.lock().unwrap();
        if q.pending.is_empty()
            && q.in_flight == 0
            && (stopping || self.source.is_exhausted())
        {
            return Next::Done;
        }
        // Everything pending is backing off (or other slots hold the
        // remaining work): sleep until the earliest becomes ready.
        let now = Instant::now();
        let wait = q
            .pending
            .iter()
            .filter_map(|a| a.ready_at)
            .map(|t| t.saturating_duration_since(now))
            .min()
            .unwrap_or(Duration::from_millis(50));
        Next::Wait(wait.clamp(Duration::from_millis(1), Duration::from_millis(250)))
    }

    fn wait_for_work(&self, d: Duration) {
        let q = self.q.lock().unwrap();
        let _ = self.cv.wait_timeout(q, d).unwrap();
    }

    /// Returns a popped-but-unstarted attempt to the queue (acquisition
    /// failure, clean worker departure, or slot retirement) without
    /// consuming a retry attempt.
    fn give_back(&self, att: Attempt) {
        let mut q = self.q.lock().unwrap();
        q.pending.push_front(att);
        q.in_flight -= 1;
        self.cv.notify_all();
    }

    fn attempt_succeeded(&self, att: Attempt, value: Json, duration_secs: f64) {
        let (spec, id) = self.task(att.index);
        if let Some(j) = &self.hooks.journal {
            j.record(&Event::TaskSucceeded {
                id: id.clone(),
                attempt: att.attempt,
                duration_secs,
            });
        }
        if let Some(m) = &self.hooks.metrics {
            m.exec_time.record(Duration::from_secs_f64(duration_secs.max(0.0)));
        }
        let outcome = TaskOutcome {
            spec,
            id,
            status: TaskStatus::Success,
            value: Some(value),
            failure: None,
            duration_secs,
            from_cache: false,
            attempts: att.attempt,
        };
        self.finish(outcome, true);
        self.release_task(att.index);
    }

    /// One attempt failed (worker-reported error/panic, or a crash). The
    /// retry policy decides between a delayed requeue and a final failure.
    fn attempt_failed(&self, att: Attempt, kind: FailureKind, message: String, duration_secs: f64) {
        if let Some(j) = &self.hooks.journal {
            if let Some((_, id)) = self.task_brief(att.index) {
                j.record(&Event::TaskFailed {
                    id,
                    attempt: att.attempt,
                    message: message.clone(),
                });
            }
        }
        self.requeue_or_fail(att, kind, message, duration_secs);
    }

    /// One attempt exceeded the per-task wall-clock budget: journaled as
    /// a **timeout** (not a crash, not an ordinary failure), counted on
    /// its own metric, then requeued-or-failed under the retry policy
    /// with kind [`FailureKind::Timeout`].
    fn attempt_timed_out(&self, att: Attempt, budget: Duration) {
        if let Some(j) = &self.hooks.journal {
            if let Some((_, id)) = self.task_brief(att.index) {
                j.record(&Event::TaskTimedOut {
                    id,
                    attempt: att.attempt,
                    budget_secs: budget.as_secs_f64(),
                });
            }
        }
        if let Some(m) = &self.hooks.metrics {
            m.tasks_timed_out.inc();
        }
        self.requeue_or_fail(
            att,
            FailureKind::Timeout,
            format!("task exceeded its per-task wall-clock budget of {budget:?}"),
            budget.as_secs_f64(),
        );
    }

    /// Shared tail of every consumed-but-unsuccessful attempt: requeue
    /// under the retry policy (with backoff), or record the final failed
    /// outcome.
    fn requeue_or_fail(
        &self,
        att: Attempt,
        kind: FailureKind,
        message: String,
        duration_secs: f64,
    ) {
        if self.opts.retry.should_retry(att.attempt) {
            if let Some(m) = &self.hooks.metrics {
                m.tasks_retried.inc();
            }
            let delay = self.opts.retry.delay_before(att.attempt + 1);
            let mut q = self.q.lock().unwrap();
            q.pending.push_back(Attempt {
                index: att.index,
                attempt: att.attempt + 1,
                ready_at: (!delay.is_zero()).then(|| Instant::now() + delay),
                // A genuine attempt ran; the capability re-route counter
                // starts fresh for the next one.
                deferrals: 0,
            });
            q.in_flight -= 1;
            self.cv.notify_all();
            return;
        }
        if let Some(m) = &self.hooks.metrics {
            m.exec_time.record(Duration::from_secs_f64(duration_secs.max(0.0)));
        }
        let outcome = self.failed_outcome(att.index, kind, message, duration_secs, att.attempt);
        self.finish(outcome, true);
        self.release_task(att.index);
    }

    /// The worker answered `Unsupported`: it does not register the
    /// experiment the task names and executed nothing. Not a worker
    /// fault and not a consumed attempt — re-route once to a capable
    /// slot (the compatible-scan in [`Shared::next_task`] steers it
    /// there), then fail with a typed, explicit outcome instead of
    /// ping-ponging between mismatched workers.
    fn attempt_unsupported(&self, att: Attempt, message: String) {
        if att.deferrals == 0 {
            let mut q = self.q.lock().unwrap();
            q.pending.push_front(Attempt {
                index: att.index,
                attempt: att.attempt,
                ready_at: None,
                deferrals: att.deferrals + 1,
            });
            q.in_flight -= 1;
            drop(q);
            self.cv.notify_all();
            return;
        }
        let message = format!("capability mismatch persisted after a re-route: {message}");
        if let Some(j) = &self.hooks.journal {
            if let Some((_, id)) = self.task_brief(att.index) {
                j.record(&Event::TaskFailed {
                    id,
                    attempt: att.attempt,
                    message: message.clone(),
                });
            }
        }
        let outcome = self.failed_outcome(
            att.index,
            FailureKind::UnknownExperiment,
            message,
            0.0,
            att.attempt,
        );
        self.finish(outcome, true);
        self.release_task(att.index);
    }

    /// Fails every pending attempt that targets an experiment no live
    /// worker registers — the explicit, journaled alternative to letting
    /// such work wait forever once its only capable worker departed.
    /// Conservative on purpose: while any slot is between workers
    /// (`Acquiring`), the next acquisition could serve anything, so
    /// nothing is failed.
    fn fail_unservable(&self) {
        // Snapshot the board first — `caps` is never locked while `q` or
        // `tasks` is held (and vice versa), so the order here is free of
        // cycles.
        let lists: Vec<Vec<String>> = {
            let caps = self.caps.lock().unwrap();
            if caps
                .iter()
                .any(|c| matches!(c, Some(CapEntry::Acquiring)))
            {
                return;
            }
            caps.iter()
                .filter_map(|c| match c {
                    Some(CapEntry::Has(Some(list))) => Some(list.clone()),
                    _ => None,
                })
                .collect()
        };
        let victims: Vec<(Attempt, String)> = {
            let mut q = self.q.lock().unwrap();
            if q.abort || q.pending.is_empty() {
                return;
            }
            let tasks = self.tasks.lock().unwrap();
            let mut keep = VecDeque::new();
            let mut out = Vec::new();
            while let Some(a) = q.pending.pop_front() {
                let name = tasks
                    .get(a.index)
                    .and_then(|t| t.spec.exp.as_ref().map(|e| e.name.clone()));
                match name {
                    // Unnamed tasks are dispatchable to any worker.
                    None => keep.push_back(a),
                    Some(n) => {
                        if lists.iter().any(|l| l.iter().any(|x| x == &n)) {
                            keep.push_back(a);
                        } else {
                            out.push((a, n));
                        }
                    }
                }
            }
            q.pending = keep;
            out
        };
        for (att, name) in victims {
            let message =
                format!("no live worker registers experiment '{name}' (task unservable)");
            if let Some(j) = &self.hooks.journal {
                if let Some((_, id)) = self.task_brief(att.index) {
                    j.record(&Event::TaskFailed {
                        id,
                        attempt: att.attempt,
                        message: message.clone(),
                    });
                }
            }
            let outcome = self.failed_outcome(
                att.index,
                FailureKind::UnknownExperiment,
                message,
                0.0,
                att.attempt.saturating_sub(1),
            );
            // Pending attempts are not in flight; `finish` still counts
            // them toward completion so nothing is dropped.
            self.finish(outcome, false);
            self.release_task(att.index);
        }
    }

    /// Cancel arrived while this attempt was executing and its worker was
    /// stopped: journal the interruption and account the spec as skipped —
    /// the task never reached a terminal outcome (no cache/checkpoint
    /// record), so a later resume re-runs it from its last saved progress.
    fn interrupt_attempt(&self, att: Attempt, message: String) {
        if let Some(j) = &self.hooks.journal {
            if let Some((_, id)) = self.task_brief(att.index) {
                j.record(&Event::TaskFailed { id, attempt: att.attempt, message });
            }
        }
        if let Some(p) = &self.hooks.progress {
            p.mark_skipped();
        }
        let spec = self.task(att.index).0;
        let mut q = self.q.lock().unwrap();
        q.skipped.push(spec);
        q.in_flight -= 1;
        drop(q);
        self.cv.notify_all();
        self.release_task(att.index);
    }

    fn failed_outcome(
        &self,
        index: usize,
        kind: FailureKind,
        message: String,
        duration_secs: f64,
        attempts: u32,
    ) -> TaskOutcome {
        let (spec, id) = self.task(index);
        let params = spec.param_strings();
        TaskOutcome {
            spec,
            id,
            status: TaskStatus::Failed,
            value: None,
            failure: Some(TaskFailure {
                kind,
                message,
                params,
                attempts,
            }),
            duration_secs,
            from_cache: false,
            attempts,
        }
    }

    /// Records a terminal outcome — counters, persistence hook, progress,
    /// fail-fast — and, for outcomes that came off the dispatch path,
    /// releases their in-flight slot (`was_in_flight`; false only for
    /// never-dispatched orphans failed at retirement).
    fn finish(&self, outcome: TaskOutcome, was_in_flight: bool) {
        let failed = outcome.status == TaskStatus::Failed;
        if let Some(t) = &self.hooks.tracer {
            t.record(outcome.spec.index, outcome.attempts, SpanState::Recorded, None, None);
        }
        if let Some(m) = &self.hooks.metrics {
            m.tasks_total.inc();
            if failed {
                m.tasks_failed.inc();
            } else {
                m.tasks_succeeded.inc();
            }
        }
        if let Some(record) = &self.hooks.record {
            record(&outcome);
        }
        if let Some(p) = &self.hooks.progress {
            p.mark_done();
        }
        let mut q = self.q.lock().unwrap();
        if failed && self.opts.fail_fast {
            q.abort = true;
        }
        q.completed += 1;
        if was_in_flight {
            q.in_flight -= 1;
        }
        drop(q);
        self.cv.notify_all();
    }

    /// A slot is done (queue drained, or crash budget exhausted). The last
    /// slot out with work still pending fails that work explicitly —
    /// nothing is ever dropped on the floor.
    fn retire_slot(&self, slot: usize, crashes_used: u32) {
        self.caps.lock().unwrap()[slot] = None;
        let mut q = self.q.lock().unwrap();
        q.live_slots -= 1;
        if crashes_used > self.opts.crash_budget {
            eprintln!(
                "memento supervisor: slot {slot} retired after {crashes_used} worker \
                 losses (budget {})",
                self.opts.crash_budget
            );
        }
        let all_retired = q.live_slots == 0;
        let aborting = q.abort;
        if all_retired && !aborting {
            let orphans: Vec<Attempt> = q.pending.drain(..).collect();
            drop(q);
            for att in orphans {
                let outcome = self.failed_outcome(
                    att.index,
                    FailureKind::Crash,
                    "no workers left: every slot exhausted its crash budget".to_string(),
                    0.0,
                    att.attempt.saturating_sub(1),
                );
                self.finish(outcome, false);
                self.release_task(att.index);
            }
            // Work the run never even pulled fails explicitly too —
            // nothing is dropped on the floor — bounded by
            // ABORT_DRAIN_LIMIT so total worker loss on a huge matrix
            // still terminates promptly (remainder flagged truncated).
            // Cancel stops this drain immediately.
            let mut failed_n = 0usize;
            while !self.cancelled() {
                if failed_n >= ABORT_DRAIN_LIMIT {
                    if !self.source.is_exhausted() {
                        self.drain_truncated.store(true, Ordering::SeqCst);
                    }
                    break;
                }
                let Some(index) = self.pull_fresh() else { break };
                failed_n += 1;
                let outcome = self.failed_outcome(
                    index,
                    FailureKind::Crash,
                    "no workers left: every slot exhausted its crash budget".to_string(),
                    0.0,
                    0,
                );
                self.finish(outcome, false);
                self.release_task(index);
            }
        }
        self.cv.notify_all();
    }
}
