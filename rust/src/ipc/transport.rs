//! Pluggable byte transports for the supervisor ↔ worker protocol.
//!
//! The wire format ([`crate::ipc::proto`]) is already transport-agnostic:
//! a frame is a length prefix plus a self-describing payload (tagged
//! binary or JSON bytes), written to anything that implements
//! `Read`/`Write`. What *was* transport-specific before this
//! module existed was the plumbing around it — `UnixListener::accept`,
//! `UnixStream::try_clone`, per-stream read timeouts, half-close — all
//! hard-wired to Unix domain sockets in the supervisor and worker.
//!
//! This module abstracts exactly that plumbing:
//!
//! - [`WireStream`] — one connected byte stream (clone for a writer half,
//!   set read deadlines, half-close the read side);
//! - [`WireListener`] — a non-blocking accept source of fresh streams;
//! - [`Endpoint`] — a connectable address, printable and parseable, so a
//!   worker can be pointed at a supervisor with one string
//!   (`/tmp/…/supervisor.sock` or `tcp://10.0.0.7:7070`);
//! - [`Transport`] — the bind-side configuration (`Unix` | `Tcp`).
//!
//! Two implementations ship: **Unix domain sockets** (the process-backend
//! default: same host, filesystem-permission trust model, lowest latency)
//! and **TCP** (the distributed tier: workers on other machines register
//! with the supervisor's [`crate::ipc::pool::WorkerPool`]). TCP peers are
//! untrusted until they present the shared token in their `Ready`
//! handshake — authentication is enforced by the pool, not here; this
//! module only moves bytes.
//!
//! # Adding a transport
//!
//! Implement [`WireStream`] for the connected-stream type and
//! [`WireListener`] for the acceptor, add an [`Endpoint`] variant with
//! `connect`/`parse`/`Display` arms, and a [`Transport`] variant with a
//! `bind` arm. Nothing in the supervisor, pool, or worker needs to change
//! — they speak trait objects end to end.

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

/// One connected, frame-capable byte stream between a supervisor and a
/// worker.
///
/// Both sides split a connection into an owned reader plus a cloned
/// writer half ([`WireStream::try_clone_stream`]); the writer half may be
/// shared behind a mutex (the worker's heartbeat thread does this).
/// Implementations must be safe to read and write concurrently from the
/// two halves, which both `UnixStream` and `TcpStream` guarantee.
pub trait WireStream: Read + Write + Send {
    /// Clones the stream handle (same underlying connection, independent
    /// file descriptor) — used to split reader and writer halves.
    fn try_clone_stream(&self) -> io::Result<Box<dyn WireStream>>;

    /// Sets (or clears, with `None`) the read deadline. The supervisor
    /// drives heartbeat-silence detection, cancel grace windows, and
    /// per-task timeouts through this.
    fn set_stream_read_timeout(&self, d: Option<Duration>) -> io::Result<()>;

    /// Half-closes the read side, failing any peer blocked writing into a
    /// full buffer (used before reaping a worker that may never drain).
    fn shutdown_read(&self) -> io::Result<()>;

    /// Closes both directions; the peer observes EOF on its next read.
    fn shutdown_both(&self) -> io::Result<()>;

    /// Human-readable peer description for log lines.
    fn peer_label(&self) -> String;
}

impl WireStream for UnixStream {
    fn try_clone_stream(&self) -> io::Result<Box<dyn WireStream>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn set_stream_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }

    fn shutdown_read(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Read)
    }

    fn shutdown_both(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }

    fn peer_label(&self) -> String {
        "unix peer".to_string()
    }
}

impl WireStream for TcpStream {
    fn try_clone_stream(&self) -> io::Result<Box<dyn WireStream>> {
        Ok(Box::new(self.try_clone()?))
    }

    fn set_stream_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.set_read_timeout(d)
    }

    fn shutdown_read(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Read)
    }

    fn shutdown_both(&self) -> io::Result<()> {
        self.shutdown(std::net::Shutdown::Both)
    }

    fn peer_label(&self) -> String {
        self.peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "tcp peer".to_string())
    }
}

/// A non-blocking accept source of fresh [`WireStream`]s.
///
/// Listeners are polled (accept returns `Ok(None)` instead of blocking on
/// `WouldBlock`) so one acceptor thread can also watch a stop flag — the
/// pattern both the supervisor's Unix acceptor and the worker pool's TCP
/// acceptor use.
pub trait WireListener: Send {
    /// Accepts one pending connection, or `Ok(None)` if none is waiting.
    fn accept_stream(&self) -> io::Result<Option<Box<dyn WireStream>>>;

    /// The endpoint workers should connect to (for TCP with a `:0` bind
    /// request, this carries the OS-assigned port).
    fn endpoint(&self) -> Endpoint;
}

/// Unix-domain-socket listener (see [`bind_unix`]).
pub struct UnixWireListener {
    listener: UnixListener,
    path: PathBuf,
}

impl WireListener for UnixWireListener {
    fn accept_stream(&self) -> io::Result<Option<Box<dyn WireStream>>> {
        match self.listener.accept() {
            Ok((stream, _addr)) => {
                stream.set_nonblocking(false)?;
                Ok(Some(Box::new(stream)))
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn endpoint(&self) -> Endpoint {
        Endpoint::Unix(self.path.clone())
    }
}

/// TCP listener (see [`bind_tcp`]).
pub struct TcpWireListener {
    listener: TcpListener,
    addr: String,
}

impl WireListener for TcpWireListener {
    fn accept_stream(&self) -> io::Result<Option<Box<dyn WireStream>>> {
        match self.listener.accept() {
            Ok((stream, _addr)) => {
                stream.set_nonblocking(false)?;
                // One frame per message and every message is
                // latency-sensitive (handshakes, dispatches, outcomes):
                // never trade latency for Nagle coalescing.
                let _ = stream.set_nodelay(true);
                Ok(Some(Box::new(stream)))
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn endpoint(&self) -> Endpoint {
        Endpoint::Tcp(self.addr.clone())
    }
}

/// Binds a non-blocking Unix-domain-socket listener at `path`.
pub fn bind_unix(path: impl Into<PathBuf>) -> io::Result<UnixWireListener> {
    let path = path.into();
    let listener = UnixListener::bind(&path)?;
    listener.set_nonblocking(true)?;
    Ok(UnixWireListener { listener, path })
}

/// Binds a non-blocking TCP listener at `addr` (e.g. `127.0.0.1:0` for an
/// OS-assigned loopback port, `0.0.0.0:7070` to accept off-machine
/// workers). The listener's [`WireListener::endpoint`] reports the actual
/// bound address.
pub fn bind_tcp(addr: &str) -> io::Result<TcpWireListener> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let actual = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.to_string());
    Ok(TcpWireListener { listener, addr: actual })
}

/// Polls `listener` until `stop` is set, invoking `on_conn` for every
/// accepted connection — the shared acceptor loop of the supervisor
/// (spawn mode) and the worker pool. The poll interval backs off 2ms →
/// 100ms while idle (steady state for a long run: everything connected
/// minutes ago) and snaps back on arrival (spawn/registration bursts).
/// Returns on `stop` or on a listener error. `on_conn` must not block
/// the loop for long — hand slow per-connection work (handshakes with
/// untrusted peers) to another thread.
pub fn poll_accept(
    listener: Box<dyn WireListener>,
    stop: &std::sync::atomic::AtomicBool,
    mut on_conn: impl FnMut(Box<dyn WireStream>),
) {
    use std::sync::atomic::Ordering;
    let mut idle_sleep = Duration::from_millis(2);
    while !stop.load(Ordering::SeqCst) {
        match listener.accept_stream() {
            Ok(Some(stream)) => {
                idle_sleep = Duration::from_millis(2);
                on_conn(stream);
            }
            Ok(None) => {
                std::thread::sleep(idle_sleep);
                idle_sleep = (idle_sleep * 2).min(Duration::from_millis(100));
            }
            Err(_) => return,
        }
    }
}

/// A connectable supervisor address, printable as a single string so it
/// can travel through an environment variable or a CLI flag.
///
/// Renderings: a Unix endpoint prints as its bare socket path; a TCP
/// endpoint prints as `tcp://host:port`. [`Endpoint::parse`] inverts both.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// A Unix domain socket path (same-host workers).
    Unix(PathBuf),
    /// A TCP `host:port` address (distributed workers).
    Tcp(String),
}

/// URI scheme prefix for TCP endpoints in their string rendering.
const TCP_SCHEME: &str = "tcp://";

impl Endpoint {
    /// Parses the string rendering produced by `Display`: anything with a
    /// `tcp://` scheme is TCP, everything else is a Unix socket path.
    pub fn parse(s: &str) -> Endpoint {
        match s.strip_prefix(TCP_SCHEME) {
            Some(addr) => Endpoint::Tcp(addr.to_string()),
            None => Endpoint::Unix(PathBuf::from(s)),
        }
    }

    /// Opens a fresh connection to this endpoint.
    pub fn connect(&self) -> io::Result<Box<dyn WireStream>> {
        match self {
            Endpoint::Unix(path) => {
                let stream = UnixStream::connect(path)?;
                Ok(Box::new(stream))
            }
            Endpoint::Tcp(addr) => {
                let stream = TcpStream::connect(addr.as_str())?;
                let _ = stream.set_nodelay(true);
                Ok(Box::new(stream))
            }
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Unix(p) => write!(f, "{}", p.display()),
            Endpoint::Tcp(a) => write!(f, "{TCP_SCHEME}{a}"),
        }
    }
}

/// Bind-side transport selection for a supervisor or worker pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Transport {
    /// A private Unix domain socket in a fresh temporary directory. The
    /// trust model is filesystem permissions; no token is required.
    Unix,
    /// A TCP listener at `bind` (`host:port`; port `0` = OS-assigned).
    /// TCP peers are untrusted: the accepting side must require the
    /// shared-token `Ready` handshake.
    Tcp {
        /// Address to bind, e.g. `"127.0.0.1:0"` or `"0.0.0.0:7070"`.
        bind: String,
    },
}

impl Transport {
    /// Binds a listener for this transport. For [`Transport::Unix`] the
    /// returned [`crate::util::fs::TempDir`] owns the socket's directory
    /// and must be kept alive as long as the listener.
    pub fn bind(
        &self,
    ) -> io::Result<(Box<dyn WireListener>, Option<crate::util::fs::TempDir>)> {
        match self {
            Transport::Unix => {
                let dir = crate::util::fs::TempDir::new("ipc")?;
                let listener = bind_unix(dir.join("supervisor.sock"))?;
                Ok((Box::new(listener), Some(dir)))
            }
            Transport::Tcp { bind } => {
                let listener = bind_tcp(bind)?;
                Ok((Box::new(listener), None))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipc::proto::{read_frame, write_frame, Msg};

    #[test]
    fn endpoint_display_parse_roundtrip() {
        let u = Endpoint::Unix(PathBuf::from("/tmp/x/supervisor.sock"));
        assert_eq!(Endpoint::parse(&u.to_string()), u);
        let t = Endpoint::Tcp("127.0.0.1:7070".to_string());
        assert_eq!(t.to_string(), "tcp://127.0.0.1:7070");
        assert_eq!(Endpoint::parse(&t.to_string()), t);
    }

    /// Frames must survive both transports unchanged: accept a connection,
    /// echo one message, and compare.
    fn roundtrip_over(listener: Box<dyn WireListener>) {
        let endpoint = listener.endpoint();
        let server = std::thread::spawn(move || {
            // Poll until the client shows up (listener is non-blocking).
            let mut stream = loop {
                if let Some(s) = listener.accept_stream().unwrap() {
                    break s;
                }
                std::thread::sleep(Duration::from_millis(2));
            };
            let msg = read_frame(&mut stream).unwrap().unwrap();
            let mut writer = stream.try_clone_stream().unwrap();
            write_frame(&mut writer, &msg).unwrap();
        });
        let mut client = endpoint.connect().unwrap();
        let sent = Msg::Heartbeat { worker: 7, busy: Some(3) };
        write_frame(&mut client, &sent).unwrap();
        let back = read_frame(&mut client).unwrap().unwrap();
        assert_eq!(back, sent);
        server.join().unwrap();
    }

    #[test]
    fn frames_roundtrip_over_unix() {
        let (listener, _dir) = Transport::Unix.bind().unwrap();
        roundtrip_over(listener);
    }

    #[test]
    fn frames_roundtrip_over_tcp_loopback() {
        let (listener, dir) = Transport::Tcp { bind: "127.0.0.1:0".to_string() }
            .bind()
            .unwrap();
        assert!(dir.is_none(), "tcp needs no socket dir");
        let Endpoint::Tcp(addr) = listener.endpoint() else {
            panic!("tcp listener must report a tcp endpoint");
        };
        assert!(!addr.ends_with(":0"), "port must be resolved, got {addr}");
        roundtrip_over(listener);
    }

    #[test]
    fn read_timeout_applies_through_the_trait() {
        let (listener, _dir) = Transport::Tcp { bind: "127.0.0.1:0".to_string() }
            .bind()
            .unwrap();
        let endpoint = listener.endpoint();
        let client = endpoint.connect().unwrap();
        client
            .set_stream_read_timeout(Some(Duration::from_millis(30)))
            .unwrap();
        let mut reader = client.try_clone_stream().unwrap();
        // Nobody writes: the read must fail with a timeout, not block.
        let err = read_frame(&mut reader).unwrap_err();
        assert!(
            matches!(
                err.kind(),
                io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
            ),
            "{err:?}"
        );
        drop(listener);
    }
}
