//! Process-isolated and distributed execution: task dispatch over a
//! std-only IPC protocol, across processes or machines.
//!
//! The thread backend ([`crate::util::pool`] + [`crate::coordinator::scheduler`])
//! contains `Err` returns and panics, but a task that **segfaults, calls
//! `abort`, leaks until the OOM killer arrives, or is `kill -9`'d** takes
//! the whole run with it — checkpoint flushing included. This module adds
//! the execution tiers that survive those: a supervisor in the
//! coordinator process driving single-task-at-a-time worker *processes* —
//! spawned locally over a Unix domain socket, or standing workers
//! (possibly on other machines) that register over TCP.
//!
//! - [`proto`] — the wire protocol: 4-byte big-endian length-prefixed
//!   frames whose payload is either the compact tagged binary codec
//!   ([`crate::util::codec`], the v3 default) or compact JSON (via
//!   [`crate::util::json`]; the debugging / pre-v3 fallback) — readers
//!   auto-detect per payload, handshakes are always JSON. Messages:
//!   `Ready`/`Hello` handshake (with shared-token auth for TCP peers and
//!   wire-format negotiation), `Task` (one attempt), `Progress`,
//!   `Heartbeat`, `Outcome`, `Goodbye`, `Reject`, `Shutdown`, plus the
//!   v6 client-facing frames ([`crate::daemon`] submissions):
//!   `Submit`/`Accepted`/`Event`/`Attach`/`Detach`.
//! - [`transport`] — the pluggable byte layer: `WireStream`/`WireListener`
//!   trait pair with Unix-socket and TCP implementations, plus the
//!   printable `Endpoint` addressing both.
//! - [`pool`] — the standing [`pool::WorkerPool`]: authenticates inbound
//!   TCP worker registrations and leases them to supervisor slots; it
//!   outlives individual runs, so worker processes are reused across many
//!   small runs.
//! - [`worker`] — the worker side: connect (or reconnect with backoff),
//!   handshake, execute attempts via the registered experiment function,
//!   stream outcomes, heartbeat from a side thread. Spawned workers are
//!   re-executions of the current binary, selected by the
//!   `MEMENTO_WORKER_SOCKET`/`MEMENTO_WORKER_ID` environment; standing
//!   remote workers run `memento serve` (or [`worker::serve_remote`]).
//! - [`supervisor`] — spawn/respawn or lease (crash budget per slot),
//!   heartbeat monitoring, per-task wall-clock timeouts, crash-requeue
//!   under the run's `RetryPolicy`, fail-fast, and the bridge back into
//!   journal/metrics/progress/cache/checkpoint.
//!
//! # Choosing a backend
//!
//! `ExecBackend::Threads` (default): lowest overhead — a task dispatch is
//! a queue push. Use it when experiment code is trusted not to bring the
//! process down.
//!
//! `ExecBackend::Processes { workers, crash_budget }`: one spawn + one
//! socket round-trip per attempt (~ms, amortized over experiment runtimes
//! of seconds+), in exchange for full crash isolation: a dead worker costs
//! one attempt of one task. Pick it for native-code experiments (FFI,
//! PJRT), memory-hungry sweeps at the OOM boundary, or any run long
//! enough that "one segfault loses everything" is unacceptable. On the
//! CLI: `memento run --isolation process`.
//!
//! `ExecBackend::Remote { addr, workers, task_timeout }`: the distributed
//! tier. The supervisor listens on TCP; `memento serve` workers — on this
//! machine or others — register with a shared token and are leased one
//! run at a time. Same exactly-once accounting as the process tier, plus
//! reconnect-with-backoff for dropped workers and an optional per-task
//! wall-clock budget. On the CLI: `memento run --isolation remote
//! --listen 0.0.0.0:7070 --token-file …`. See the README's *Distributed
//! mode* section and `docs/ARCHITECTURE.md` for the full walkthrough.
//!
//! One layer further up, the [`crate::daemon`] module reuses all of this
//! — the transport, the token handshake, and one shared standing
//! [`pool::WorkerPool`] — to serve *many* runs from many clients out of
//! a single long-running process (`memento daemon` / `memento submit`).

pub mod pool;
pub mod proto;
pub mod supervisor;
pub mod transport;
pub mod worker;
