//! Process-isolated execution: multi-process task dispatch over a
//! std-only IPC protocol.
//!
//! The thread backend ([`crate::util::pool`] + [`crate::coordinator::scheduler`])
//! contains `Err` returns and panics, but a task that **segfaults, calls
//! `abort`, leaks until the OOM killer arrives, or is `kill -9`'d** takes
//! the whole run with it — checkpoint flushing included. This module adds
//! the execution tier that survives those: a supervisor in the coordinator
//! process and N single-task-at-a-time worker *processes*, connected by a
//! Unix domain socket.
//!
//! - [`proto`] — the wire protocol: 4-byte big-endian length-prefixed
//!   frames of compact JSON (via [`crate::util::json`]; no external
//!   crates). Messages: `Ready`/`Hello` handshake, `Task` (one attempt),
//!   `Progress`, `Heartbeat`, `Outcome`, `Shutdown`.
//! - [`worker`] — the worker side: connect, handshake, execute attempts
//!   via the registered experiment function, stream outcomes, heartbeat
//!   from a side thread. Workers are re-executions of the current binary,
//!   selected by the `MEMENTO_WORKER_SOCKET`/`MEMENTO_WORKER_ID`
//!   environment; the `memento` CLI routes them through its hidden
//!   `worker` subcommand, and library binaries are intercepted inside
//!   `Memento::run` itself.
//! - [`supervisor`] — spawn/respawn (crash budget per slot), heartbeat
//!   monitoring, crash-requeue under the run's `RetryPolicy`, fail-fast,
//!   and the bridge back into journal/metrics/progress/cache/checkpoint.
//!
//! # Choosing a backend
//!
//! `ExecBackend::Threads` (default): lowest overhead — a task dispatch is
//! a queue push. Use it when experiment code is trusted not to bring the
//! process down.
//!
//! `ExecBackend::Processes { workers, crash_budget }`: one spawn + one
//! socket round-trip per attempt (~ms, amortized over experiment runtimes
//! of seconds+), in exchange for full crash isolation: a dead worker costs
//! one attempt of one task. Pick it for native-code experiments (FFI,
//! PJRT), memory-hungry sweeps at the OOM boundary, or any run long
//! enough that "one segfault loses everything" is unacceptable. On the
//! CLI: `memento run --isolation process`.
//!
//! This tier is also the stepping stone to the ROADMAP's multi-machine
//! sharding: the protocol already carries everything a remote worker
//! needs (specs, settings, seeds, version), leaving only the transport to
//! generalize.

pub mod proto;
pub mod supervisor;
pub mod worker;
