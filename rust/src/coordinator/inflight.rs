//! Cross-run in-flight execution gate.
//!
//! When several concurrent runs share one result store (the daemon's
//! steady state — see [`crate::daemon`]), the per-run restore filter is
//! not enough to guarantee daemon-wide execute-once: two runs can probe
//! the cache for the same task id in the same instant, both miss, and
//! both execute the cell. The [`InflightGate`] closes that window with a
//! process-wide claim table keyed by task id:
//!
//! - a run's restore filter **claims** an id after its cache probe
//!   misses and before the spec enters the execution queue;
//! - a second run hitting the same id parks on the gate instead of
//!   executing, and **re-probes the cache** each time it wakes — the
//!   owning run records its result *before* releasing, so the waiter's
//!   next probe restores the value without executing;
//! - the owning run **releases** the id from its record hook (terminal
//!   outcome), and releases every claim it still holds when the run
//!   winds down (covering aborted/cancelled runs whose claimed tasks
//!   were skipped and therefore never reached the record hook).
//!
//! Claims are owned: a release only removes the entry when the caller's
//! run label matches the claimant, so the release calls sprinkled along
//! the outcome paths are harmless no-ops for unclaimed ids.
//!
//! The gate deliberately knows nothing about tasks or stores — it is a
//! `Mutex<HashMap> + Condvar` keyed by opaque strings, installed via
//! [`crate::coordinator::memento::Memento::with_inflight_gate`]. With a
//! gate installed, the supervised backends also skip their
//! exclusive-cache optimization (see
//! [`crate::coordinator::cache::ResultCache::set_exclusive`]): the
//! whole point of the gate is that *other* writers are active, so the
//! cache index must keep tolerating them.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Outcome of [`InflightGate::try_claim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Claim {
    /// The id was free (or already held by this run); the caller now owns
    /// it and must release it via [`InflightGate::release`] or
    /// [`InflightGate::release_run`].
    Claimed,
    /// Another run is executing this id right now. Park on
    /// [`InflightGate::wait_released`] and re-probe the cache.
    InFlightElsewhere,
}

/// Process-wide claim table mapping in-flight task ids to the run label
/// executing them. See the [module docs](self) for the protocol.
pub struct InflightGate {
    claims: Mutex<HashMap<String, String>>,
    released: Condvar,
}

impl InflightGate {
    /// Creates an empty gate, ready to share across runs.
    pub fn new() -> Arc<InflightGate> {
        Arc::new(InflightGate {
            claims: Mutex::new(HashMap::new()),
            released: Condvar::new(),
        })
    }

    /// Attempts to claim `id` for `run`. Re-claiming an id the same run
    /// already holds succeeds (idempotent — a retried attempt passes
    /// through the filter only once, but defensive callers cost nothing).
    pub fn try_claim(&self, id: &str, run: &str) -> Claim {
        let mut claims = self.claims.lock().unwrap();
        match claims.get(id) {
            Some(owner) if owner != run => Claim::InFlightElsewhere,
            Some(_) => Claim::Claimed,
            None => {
                claims.insert(id.to_string(), run.to_string());
                Claim::Claimed
            }
        }
    }

    /// Blocks until `id` is released or `timeout` elapses; returns `true`
    /// when the id is free at wake-up. Callers loop around this with a
    /// fresh cache probe per wake-up — a `false` return is not an error,
    /// just a cue to re-check cancellation before parking again.
    pub fn wait_released(&self, id: &str, timeout: Duration) -> bool {
        let claims = self.claims.lock().unwrap();
        if !claims.contains_key(id) {
            return true;
        }
        let (claims, _timed_out) = self
            .released
            .wait_timeout_while(claims, timeout, |c| c.contains_key(id))
            .unwrap();
        !claims.contains_key(id)
    }

    /// Releases `id` if (and only if) `run` is the claimant, waking every
    /// parked waiter. Call *after* recording the outcome so waiters'
    /// re-probes see the value.
    pub fn release(&self, id: &str, run: &str) {
        let mut claims = self.claims.lock().unwrap();
        if claims.get(id).is_some_and(|owner| owner == run) {
            claims.remove(id);
            drop(claims);
            self.released.notify_all();
        }
    }

    /// Releases every claim still held by `run` — the wind-down sweep
    /// covering tasks that were claimed but skipped (abort, cancel,
    /// fail-fast) and so never reached the record hook.
    pub fn release_run(&self, run: &str) {
        let mut claims = self.claims.lock().unwrap();
        let before = claims.len();
        claims.retain(|_, owner| owner != run);
        if claims.len() != before {
            drop(claims);
            self.released.notify_all();
        }
    }

    /// Number of ids currently claimed (all runs).
    pub fn in_flight(&self) -> usize {
        self.claims.lock().unwrap().len()
    }

    /// RAII wind-down sweep: returns a guard whose `Drop` runs
    /// [`InflightGate::release_run`] for `run`, so every exit path of a
    /// run body — including panics — releases its claims.
    pub fn run_guard(self: &Arc<Self>, run: &str) -> RunGuard {
        RunGuard {
            gate: Arc::clone(self),
            run: run.to_string(),
        }
    }
}

/// Guard returned by [`InflightGate::run_guard`].
pub struct RunGuard {
    gate: Arc<InflightGate>,
    run: String,
}

impl Drop for RunGuard {
    fn drop(&mut self) {
        self.gate.release_run(&self.run);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_is_exclusive_across_runs_and_idempotent_within() {
        let gate = InflightGate::new();
        assert_eq!(gate.try_claim("t1", "a"), Claim::Claimed);
        assert_eq!(gate.try_claim("t1", "a"), Claim::Claimed);
        assert_eq!(gate.try_claim("t1", "b"), Claim::InFlightElsewhere);
        assert_eq!(gate.in_flight(), 1);
        gate.release("t1", "b"); // non-owner: no-op
        assert_eq!(gate.try_claim("t1", "b"), Claim::InFlightElsewhere);
        gate.release("t1", "a");
        assert_eq!(gate.try_claim("t1", "b"), Claim::Claimed);
    }

    #[test]
    fn wait_released_wakes_on_release() {
        let gate = InflightGate::new();
        assert_eq!(gate.try_claim("t1", "a"), Claim::Claimed);
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.wait_released("t1", Duration::from_secs(10)))
        };
        // Give the waiter a moment to park, then release.
        std::thread::sleep(Duration::from_millis(50));
        gate.release("t1", "a");
        assert!(waiter.join().unwrap(), "waiter saw the release");
    }

    #[test]
    fn run_guard_sweeps_leftover_claims() {
        let gate = InflightGate::new();
        assert_eq!(gate.try_claim("t1", "a"), Claim::Claimed);
        assert_eq!(gate.try_claim("t2", "a"), Claim::Claimed);
        assert_eq!(gate.try_claim("t3", "b"), Claim::Claimed);
        {
            let _guard = gate.run_guard("a");
        }
        assert_eq!(gate.in_flight(), 1, "run a's claims swept, b's kept");
        assert_eq!(gate.try_claim("t1", "b"), Claim::Claimed);
    }

    #[test]
    fn wait_released_times_out_while_held() {
        let gate = InflightGate::new();
        assert_eq!(gate.try_claim("t1", "a"), Claim::Claimed);
        assert!(!gate.wait_released("t1", Duration::from_millis(20)));
        assert!(gate.wait_released("t2", Duration::from_millis(20)));
    }
}
