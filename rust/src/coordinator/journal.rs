//! Run journal: an append-only JSONL event log of the task lifecycle.
//!
//! Complements the checkpoint manifest (which holds *state*) with a
//! *history*: task started / finished / failed / retried / restored events
//! with timestamps, durations, and worker attribution. `memento status`
//! and post-hoc debugging ("which task ran when, on which worker, and how
//! often was it retried?") read this. One line per event, flushed on every
//! write — the journal is an audit trail, so durability beats batching.
//!
//! Lines stay JSON text (an audit trail should be `grep`-able, and
//! line-framing and binary payloads don't mix), but [`Journal::replay`]
//! reads them with the lazy field scanner ([`crate::util::scan`]): each
//! line's named fields are extracted in one skip-pass without building a
//! per-line [`Json`] tree.

use crate::coordinator::task::TaskId;
use crate::util::json::Json;
use crate::util::scan::Scanner;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// One journal event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// An attempt was dispatched (one per attempt, so retries repeat it).
    TaskStarted {
        /// Task identity (content hash of params + version).
        id: TaskId,
        /// 1-based attempt number.
        attempt: u32,
    },
    /// An attempt returned a successful result.
    TaskSucceeded {
        /// Task identity.
        id: TaskId,
        /// The attempt that succeeded.
        attempt: u32,
        /// Wall-clock execution time of the successful attempt.
        duration_secs: f64,
    },
    /// An attempt failed (experiment error, contained panic, worker
    /// crash, or a cancel interruption — the message distinguishes them).
    TaskFailed {
        /// Task identity.
        id: TaskId,
        /// The attempt that failed.
        attempt: u32,
        /// Human-readable failure description.
        message: String,
    },
    /// An attempt was stopped for exceeding the per-task wall-clock
    /// budget (`--task-timeout`). Recorded as its own kind — distinct
    /// from `TaskFailed` — so post-hoc analysis can separate runaway
    /// configurations from genuinely failing ones. The retry policy may
    /// redispatch the task afterwards (a fresh `TaskStarted` follows).
    TaskTimedOut {
        /// Task identity.
        id: TaskId,
        /// The attempt that was stopped.
        attempt: u32,
        /// The budget the attempt exceeded, in seconds.
        budget_secs: f64,
    },
    /// A task's result was restored from cache or a resumed checkpoint
    /// without executing.
    TaskRestored {
        /// Task identity.
        id: TaskId,
    },
}

impl Event {
    fn kind(&self) -> &'static str {
        match self {
            Event::TaskStarted { .. } => "started",
            Event::TaskSucceeded { .. } => "succeeded",
            Event::TaskFailed { .. } => "failed",
            Event::TaskTimedOut { .. } => "timed_out",
            Event::TaskRestored { .. } => "restored",
        }
    }

    fn id(&self) -> &TaskId {
        match self {
            Event::TaskStarted { id, .. }
            | Event::TaskSucceeded { id, .. }
            | Event::TaskFailed { id, .. }
            | Event::TaskTimedOut { id, .. }
            | Event::TaskRestored { id } => id,
        }
    }

    fn to_json(&self, unix_secs: f64) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("ts", Json::Num(unix_secs)),
            ("event", Json::str(self.kind())),
            ("task", Json::str(self.id().0.clone())),
        ];
        match self {
            Event::TaskStarted { attempt, .. } => {
                fields.push(("attempt", Json::int(*attempt as i64)));
            }
            Event::TaskSucceeded { attempt, duration_secs, .. } => {
                fields.push(("attempt", Json::int(*attempt as i64)));
                fields.push(("duration_secs", Json::Num(*duration_secs)));
            }
            Event::TaskFailed { attempt, message, .. } => {
                fields.push(("attempt", Json::int(*attempt as i64)));
                fields.push(("message", Json::str(message.clone())));
            }
            Event::TaskTimedOut { attempt, budget_secs, .. } => {
                fields.push(("attempt", Json::int(*attempt as i64)));
                fields.push(("budget_secs", Json::Num(*budget_secs)));
            }
            Event::TaskRestored { .. } => {}
        }
        Json::obj(fields)
    }

    /// Parses an event line back (best-effort; unknown kinds → None).
    pub fn from_json(j: &Json) -> Option<(f64, Event)> {
        let ts = j.get("ts")?.as_f64()?;
        let id = TaskId(j.get("task")?.as_str()?.to_string());
        let attempt = j.get("attempt").and_then(|a| a.as_i64()).unwrap_or(1) as u32;
        let ev = match j.get("event")?.as_str()? {
            "started" => Event::TaskStarted { id, attempt },
            "succeeded" => Event::TaskSucceeded {
                id,
                attempt,
                duration_secs: j.get("duration_secs").and_then(|d| d.as_f64()).unwrap_or(0.0),
            },
            "failed" => Event::TaskFailed {
                id,
                attempt,
                message: j
                    .get("message")
                    .and_then(|m| m.as_str())
                    .unwrap_or("")
                    .to_string(),
            },
            "timed_out" => Event::TaskTimedOut {
                id,
                attempt,
                budget_secs: j
                    .get("budget_secs")
                    .and_then(|d| d.as_f64())
                    .unwrap_or(0.0),
            },
            "restored" => Event::TaskRestored { id },
            _ => return None,
        };
        Some((ts, ev))
    }

    /// Parses one journal line by scanning its fields in place — the
    /// replay-path equivalent of [`Event::from_json`] that never builds a
    /// [`Json`] tree. Best-effort like its sibling: `None` for garbage
    /// lines and unknown kinds.
    fn from_line(line: &str) -> Option<(f64, Event)> {
        let scanner = Scanner::new(line.as_bytes()).ok()?;
        let [ts, kind, task, attempt, duration, message, budget] = scanner
            .fields([
                "ts",
                "event",
                "task",
                "attempt",
                "duration_secs",
                "message",
                "budget_secs",
            ])
            .ok()?;
        let ts = ts.as_ref().and_then(|v| v.as_f64())?;
        let id = TaskId(task.as_ref().and_then(|v| v.as_str())?.to_string());
        let attempt = attempt.as_ref().and_then(|a| a.as_i64()).unwrap_or(1) as u32;
        let ev = match kind.as_ref().and_then(|k| k.as_str())? {
            "started" => Event::TaskStarted { id, attempt },
            "succeeded" => Event::TaskSucceeded {
                id,
                attempt,
                duration_secs: duration.as_ref().and_then(|d| d.as_f64()).unwrap_or(0.0),
            },
            "failed" => Event::TaskFailed {
                id,
                attempt,
                message: message
                    .as_ref()
                    .and_then(|m| m.as_str())
                    .unwrap_or("")
                    .to_string(),
            },
            "timed_out" => Event::TaskTimedOut {
                id,
                attempt,
                budget_secs: budget.as_ref().and_then(|d| d.as_f64()).unwrap_or(0.0),
            },
            "restored" => Event::TaskRestored { id },
            _ => return None,
        };
        Some((ts, ev))
    }
}

/// Append-only journal writer (thread-safe).
pub struct Journal {
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Journal").field("path", &self.path).finish()
    }
}

impl Journal {
    /// Opens (appending) a journal file, creating parents as needed.
    pub fn open(path: impl Into<PathBuf>) -> std::io::Result<Journal> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(Journal { path, file: Mutex::new(file) })
    }

    /// The journal file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Appends one event (flushed immediately).
    pub fn record(&self, event: &Event) {
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        let line = event.to_json(now).to_string();
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{line}");
        let _ = f.flush();
    }

    /// Reads every parseable event back, in order. Each line is
    /// field-scanned in place — replay allocates the events, never a
    /// per-line [`Json`] tree.
    pub fn replay(path: &Path) -> std::io::Result<Vec<(f64, Event)>> {
        let text = std::fs::read_to_string(path)?;
        Ok(text.lines().filter_map(Event::from_line).collect())
    }

    /// Summarizes a journal: per-kind counts and total busy time.
    pub fn summarize(path: &Path) -> std::io::Result<JournalSummary> {
        let events = Self::replay(path)?;
        let mut s = JournalSummary::default();
        for (_, ev) in &events {
            match ev {
                Event::TaskStarted { .. } => s.started += 1,
                Event::TaskSucceeded { duration_secs, .. } => {
                    s.succeeded += 1;
                    s.busy_secs += duration_secs;
                }
                Event::TaskFailed { .. } => s.failed_attempts += 1,
                Event::TaskTimedOut { .. } => s.timeouts += 1,
                Event::TaskRestored { .. } => s.restored += 1,
            }
        }
        s.events = events.len();
        Ok(s)
    }
}

/// Aggregate view of a journal file.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct JournalSummary {
    /// Parseable lines in the journal.
    pub events: usize,
    /// `started` events (one per dispatched attempt, retries included).
    pub started: usize,
    /// `succeeded` events (exactly one per successful task).
    pub succeeded: usize,
    /// `failed` events (failed *attempts*, not final task failures).
    pub failed_attempts: usize,
    /// `timed_out` events (attempts stopped at the per-task budget).
    pub timeouts: usize,
    /// `restored` events (cache/checkpoint restores).
    pub restored: usize,
    /// Total execution time across successful attempts.
    pub busy_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn tid(n: u8) -> TaskId {
        TaskId(format!("{n:064x}"))
    }

    #[test]
    fn record_and_replay_roundtrip() {
        let td = TempDir::new("journal").unwrap();
        let path = td.join("run/journal.jsonl");
        let j = Journal::open(&path).unwrap();
        j.record(&Event::TaskStarted { id: tid(1), attempt: 1 });
        j.record(&Event::TaskFailed { id: tid(1), attempt: 1, message: "oom".into() });
        j.record(&Event::TaskStarted { id: tid(1), attempt: 2 });
        j.record(&Event::TaskSucceeded { id: tid(1), attempt: 2, duration_secs: 0.5 });
        j.record(&Event::TaskRestored { id: tid(2) });

        let events = Journal::replay(&path).unwrap();
        assert_eq!(events.len(), 5);
        assert_eq!(events[0].1, Event::TaskStarted { id: tid(1), attempt: 1 });
        assert!(matches!(&events[1].1, Event::TaskFailed { message, .. } if message == "oom"));
        // timestamps monotone non-decreasing
        for w in events.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn summarize_counts() {
        let td = TempDir::new("journal2").unwrap();
        let path = td.join("j.jsonl");
        let j = Journal::open(&path).unwrap();
        for i in 0..3u8 {
            j.record(&Event::TaskStarted { id: tid(i), attempt: 1 });
            j.record(&Event::TaskSucceeded { id: tid(i), attempt: 1, duration_secs: 1.0 });
        }
        j.record(&Event::TaskFailed { id: tid(9), attempt: 1, message: "x".into() });
        let s = Journal::summarize(&path).unwrap();
        assert_eq!(s.started, 3);
        assert_eq!(s.succeeded, 3);
        assert_eq!(s.failed_attempts, 1);
        assert!((s.busy_secs - 3.0).abs() < 1e-9);
    }

    #[test]
    fn timeout_events_roundtrip_and_summarize() {
        let td = TempDir::new("journal-timeout").unwrap();
        let path = td.join("j.jsonl");
        let j = Journal::open(&path).unwrap();
        j.record(&Event::TaskStarted { id: tid(1), attempt: 1 });
        j.record(&Event::TaskTimedOut { id: tid(1), attempt: 1, budget_secs: 0.5 });
        j.record(&Event::TaskStarted { id: tid(1), attempt: 2 });
        j.record(&Event::TaskSucceeded { id: tid(1), attempt: 2, duration_secs: 0.1 });

        let events = Journal::replay(&path).unwrap();
        assert_eq!(
            events[1].1,
            Event::TaskTimedOut { id: tid(1), attempt: 1, budget_secs: 0.5 }
        );
        let s = Journal::summarize(&path).unwrap();
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.started, 2);
        assert_eq!(s.succeeded, 1);
        assert_eq!(s.failed_attempts, 0, "a timeout is not a failed attempt");
    }

    #[test]
    fn corrupt_lines_are_skipped() {
        let td = TempDir::new("journal3").unwrap();
        let path = td.join("j.jsonl");
        let j = Journal::open(&path).unwrap();
        j.record(&Event::TaskRestored { id: tid(0) });
        // inject garbage
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{not json").unwrap();
            writeln!(f, "{{\"event\": \"martian\", \"ts\": 0, \"task\": \"x\"}}").unwrap();
        }
        j.record(&Event::TaskRestored { id: tid(1) });
        let events = Journal::replay(&path).unwrap();
        assert_eq!(events.len(), 2);
    }

    #[test]
    fn replay_scans_without_materializing_json_trees() {
        let td = TempDir::new("journal-scan").unwrap();
        let path = td.join("j.jsonl");
        let j = Journal::open(&path).unwrap();
        for i in 0..20u8 {
            j.record(&Event::TaskStarted { id: tid(i), attempt: 1 });
            j.record(&Event::TaskSucceeded { id: tid(i), attempt: 1, duration_secs: 0.25 });
        }
        j.record(&Event::TaskTimedOut { id: tid(21), attempt: 1, budget_secs: 1.5 });
        let before = crate::util::scan::materialized_count();
        let events = Journal::replay(&path).unwrap();
        assert_eq!(events.len(), 41);
        assert_eq!(
            crate::util::scan::materialized_count(),
            before,
            "replay must field-scan lines, not build Json trees"
        );
        // The scan parser agrees with the tree parser line by line.
        let text = std::fs::read_to_string(&path).unwrap();
        for (line, scanned) in text.lines().zip(&events) {
            let tree = Event::from_json(&crate::util::json::parse(line).unwrap()).unwrap();
            assert_eq!(&tree, scanned);
        }
    }

    #[test]
    fn concurrent_appends_keep_all_lines() {
        let td = TempDir::new("journal4").unwrap();
        let path = td.join("j.jsonl");
        let j = std::sync::Arc::new(Journal::open(&path).unwrap());
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let j = std::sync::Arc::clone(&j);
            handles.push(std::thread::spawn(move || {
                for i in 0..50u8 {
                    j.record(&Event::TaskStarted { id: tid(t * 50 + i), attempt: 1 });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(Journal::replay(&path).unwrap().len(), 200);
    }
}
