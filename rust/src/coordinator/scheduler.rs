//! Task scheduling across the worker pool — batched, work-stealing.
//!
//! The scheduler is deliberately generic: it takes fully-formed task specs
//! and a job closure producing a [`TaskOutcome`], and guarantees
//!
//! 1. every spec is executed **exactly once** (or skipped after abort),
//! 2. worker panics *outside* the job's own catch (bugs in the coordinator
//!    itself) cannot lose outcomes silently — missing outcomes are detected
//!    and surfaced,
//! 3. fail-fast mode stops launching new tasks after the first failure
//!    while letting in-flight tasks finish; skipped specs are returned,
//!    marked on the progress bar, and **excluded** from timing metrics so
//!    abort noise never pollutes dispatch-overhead numbers.
//!
//! # Dispatch design (why this is fast)
//!
//! The original implementation boxed one closure per spec and cloned four
//! `Arc`s into it, then pushed every box through a single-mutex queue and
//! collected outcomes over an `mpsc` channel — five allocations plus two
//! contended queues *per task*. For 10k no-op tasks the orchestrator was
//! the workload.
//!
//! Now the specs live in one shared `Arc<[TaskSpec]>` and are dispatched as
//! **chunks**: each pool job owns a contiguous index range and one
//! `Arc<ChunkCtx>` clone, walks its range, and merges its outcomes into the
//! shared collection vector with a single lock acquisition per chunk.
//! Chunks are striped across the pool's per-worker deques
//! ([`crate::util::pool`]); a worker that drains its own chunks early
//! *steals* chunks from busy siblings, so imbalance self-corrects at chunk
//! granularity without any central queue. Per-task cost amortizes to
//! `chunk_cost / chunk_len`: no per-task boxing, no per-task channel send,
//! no per-task Arc traffic.
//!
//! Exactly-once follows from construction: chunk ranges partition
//! `0..specs.len()` and the pool runs each submitted job exactly once.
//!
//! The cache/retry/checkpoint/notification pipeline around each task is
//! composed by [`crate::coordinator::memento`], keeping this module small
//! and testable in isolation.

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::progress::ProgressState;
use crate::coordinator::results::{TaskOutcome, TaskStatus};
use crate::coordinator::task::TaskSpec;
use crate::util::pool::ThreadPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Which execution tier runs the tasks.
///
/// Chosen per run via `Memento::backend` (or `--isolation` on the CLI)
/// and threaded from there through the scheduler layer:
///
/// - [`ExecBackend::Threads`] — the in-process work-stealing pool
///   ([`run_all`]). Cheapest dispatch; contains `Err`s and panics, but a
///   segfault/abort/OOM-kill in any task destroys the whole run.
/// - [`ExecBackend::Processes`] — N isolated worker *processes* driven by
///   [`crate::ipc::supervisor`]. A dying worker costs one attempt of one
///   task: the supervisor requeues it under the run's `RetryPolicy` and
///   respawns the worker, up to `crash_budget` respawns per slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// In-process worker threads (the default).
    Threads,
    /// Isolated worker processes over the std-only IPC protocol.
    Processes {
        /// Worker processes to run concurrently.
        workers: usize,
        /// Worker respawns allowed per slot before it retires.
        crash_budget: u32,
    },
}

impl Default for ExecBackend {
    fn default() -> Self {
        ExecBackend::Threads
    }
}

/// Scheduling configuration.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Worker threads. Defaults to the machine's logical CPU count.
    pub workers: usize,
    /// Stop dispatching after the first failed task.
    pub fail_fast: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { workers: crate::util::pool::num_cpus(), fail_fast: false }
    }
}

/// Load-balance evidence for one `run_all` invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchStats {
    /// Number of chunk jobs submitted to the pool.
    pub chunks: usize,
    /// Specs per chunk (last chunk may be shorter).
    pub chunk_len: usize,
    /// Chunks a worker took from a sibling's queue.
    pub steals: usize,
    /// Chunks a worker took from its own queue.
    pub local_pops: usize,
    /// Jobs whose `job` closure panicked (coordinator bugs; outcome lost).
    pub job_panics: usize,
}

/// What happened to each dispatched spec.
pub struct ScheduleReport {
    /// Outcomes for tasks that ran (or were restored); ordered by spec index.
    pub outcomes: Vec<TaskOutcome>,
    /// Specs skipped because fail-fast aborted the run.
    pub skipped: Vec<TaskSpec>,
    /// True if fail-fast triggered.
    pub aborted: bool,
    /// Dispatch/steal counters for this run.
    pub stats: DispatchStats,
}

/// Everything a chunk job needs, shared once instead of cloned per task.
struct ChunkCtx {
    specs: Arc<[TaskSpec]>,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    abort: AtomicBool,
    fail_fast: bool,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
    outcomes: Mutex<Vec<TaskOutcome>>,
    skipped: Mutex<Vec<TaskSpec>>,
    job_panics: AtomicUsize,
}

/// Chunk length for `n` specs on `workers` threads: aim for ~8 chunks per
/// worker so stealing has granules to balance with, capped so one chunk
/// never monopolizes a worker's outcome buffer.
fn chunk_len(n: usize, workers: usize) -> usize {
    (n / (workers * 8)).clamp(1, 64)
}

/// Runs `job` over all `specs` on a pool of `opts.workers` threads.
///
/// `job` must itself be panic-safe (it converts experiment panics into
/// failed outcomes); a panic escaping `job` is a coordinator bug and is
/// contained per-task, counted in [`DispatchStats::job_panics`], and
/// surfaced loudly — the run still accounts for every other task.
pub fn run_all(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
) -> ScheduleReport {
    run_all_with_metrics(specs, opts, job, progress, None)
}

/// [`run_all`] with a metrics registry: records per-chunk queue wait
/// (submission → first task start) into `dispatch_overhead`, plus
/// steal/skip counters at the end of the run. Skipped (fail-fast) specs
/// never contribute dispatch samples.
pub fn run_all_with_metrics(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
) -> ScheduleReport {
    let n = specs.len();
    if n == 0 {
        return ScheduleReport {
            outcomes: Vec::new(),
            skipped: Vec::new(),
            aborted: false,
            stats: DispatchStats::default(),
        };
    }
    let workers = opts.workers.max(1).min(n);
    let clen = chunk_len(n, workers);
    let n_chunks = (n + clen - 1) / clen;

    let ctx = Arc::new(ChunkCtx {
        specs: specs.into(),
        job,
        abort: AtomicBool::new(false),
        fail_fast: opts.fail_fast,
        progress,
        metrics: metrics.clone(),
        outcomes: Mutex::new(Vec::with_capacity(n)),
        skipped: Mutex::new(Vec::new()),
        job_panics: AtomicUsize::new(0),
    });

    let pool = ThreadPool::new(workers);
    let jobs: Vec<_> = (0..n_chunks)
        .map(|c| {
            let ctx = Arc::clone(&ctx);
            let lo = c * clen;
            let hi = (lo + clen).min(n);
            let submitted = Instant::now();
            move || run_chunk(&ctx, lo, hi, submitted)
        })
        .collect();
    pool.execute_batch(jobs);
    pool.join();
    let pool_stats = pool.stats();
    drop(pool);

    let aborted = ctx.abort.load(Ordering::SeqCst);
    // All chunk jobs are done and dropped, so this Arc is unique; the
    // fallback drain covers the (theoretical) case of a job box not yet
    // deallocated.
    let (mut outcomes, mut skipped, job_panics) = match Arc::try_unwrap(ctx) {
        Ok(ctx) => (
            ctx.outcomes.into_inner().unwrap(),
            ctx.skipped.into_inner().unwrap(),
            ctx.job_panics.load(Ordering::SeqCst),
        ),
        Err(ctx) => (
            std::mem::take(&mut *ctx.outcomes.lock().unwrap()),
            std::mem::take(&mut *ctx.skipped.lock().unwrap()),
            ctx.job_panics.load(Ordering::SeqCst),
        ),
    };

    let lost = n - outcomes.len() - skipped.len();
    if lost > 0 {
        // Coordinator-level bug: account for it loudly rather than silently.
        eprintln!(
            "memento scheduler: {lost} task(s) lost to unexpected job panics \
             ({job_panics} contained)"
        );
    }
    outcomes.sort_by_key(|o| o.spec.index);
    skipped.sort_by_key(|s| s.index);

    let stats = DispatchStats {
        chunks: n_chunks,
        chunk_len: clen,
        steals: pool_stats.steals,
        local_pops: pool_stats.local_pops,
        job_panics,
    };
    if let Some(m) = &metrics {
        m.dispatch_chunks.add(n_chunks as u64);
        m.steals.add(stats.steals as u64);
        m.tasks_skipped.add(skipped.len() as u64);
    }

    ScheduleReport { outcomes, skipped, aborted, stats }
}

/// Executes specs `lo..hi`; called on a pool worker.
fn run_chunk(ctx: &ChunkCtx, lo: usize, hi: usize, submitted: Instant) {
    let mut done: Vec<TaskOutcome> = Vec::with_capacity(hi - lo);
    let mut skip: Vec<TaskSpec> = Vec::new();
    let mut recorded_wait = false;
    for i in lo..hi {
        let spec = &ctx.specs[i];
        if ctx.abort.load(Ordering::SeqCst) {
            skip.push(spec.clone());
            if let Some(p) = &ctx.progress {
                p.mark_skipped();
            }
            continue;
        }
        if !recorded_wait {
            recorded_wait = true;
            // One queue-wait sample per chunk, and only for chunks that
            // actually execute work — skipped specs stay out of the timer.
            if let Some(m) = &ctx.metrics {
                m.dispatch_overhead.record(submitted.elapsed());
            }
        }
        match catch_unwind(AssertUnwindSafe(|| (ctx.job)(spec))) {
            Ok(outcome) => {
                if ctx.fail_fast && outcome.status == TaskStatus::Failed {
                    ctx.abort.store(true, Ordering::SeqCst);
                }
                if let Some(p) = &ctx.progress {
                    p.mark_done();
                }
                done.push(outcome);
            }
            Err(_) => {
                // Panic escaping `job` — contained so the rest of the chunk
                // (and run) still completes; counted and surfaced above.
                ctx.job_panics.fetch_add(1, Ordering::SeqCst);
                if let Some(p) = &ctx.progress {
                    p.mark_done();
                }
            }
        }
    }
    if !done.is_empty() {
        ctx.outcomes.lock().unwrap().extend(done);
    }
    if !skip.is_empty() {
        ctx.skipped.lock().unwrap().extend(skip);
    }
}

/// The pre-batching reference implementation: one boxed closure, four Arc
/// clones, and one channel send **per task**.
///
/// Note what this baseline does and does not reproduce: it submits through
/// the *current* work-stealing pool (the old single-`Mutex<VecDeque>` pool
/// no longer exists in the build), so an A/B against [`run_all`] isolates
/// the **per-task boxing + Arc + channel overhead vs chunked dispatch** —
/// it does *not* include the old central-queue contention, which was
/// removed for both paths by the pool rewrite. Treat recorded speedups as
/// a lower bound on the full improvement over the seed design.
///
/// Semantically equivalent to [`run_all`] (exactly-once, fail-fast,
/// panic containment) and retained so `benches/scheduler.rs` can measure
/// the dispatch-overhead delta on the same build — the before/after
/// evidence in `BENCH_sched_cache.json`.
pub fn run_all_unbatched(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
) -> ScheduleReport {
    let n = specs.len();
    if n == 0 {
        return ScheduleReport {
            outcomes: Vec::new(),
            skipped: Vec::new(),
            aborted: false,
            stats: DispatchStats::default(),
        };
    }
    let workers = opts.workers.max(1).min(n);
    let pool = ThreadPool::new(workers);
    let (tx, rx) = mpsc::channel::<Result<TaskOutcome, TaskSpec>>();
    let abort = Arc::new(AtomicBool::new(false));
    let fail_fast = opts.fail_fast;

    for spec in specs {
        let tx = tx.clone();
        let job = Arc::clone(&job);
        let abort = Arc::clone(&abort);
        let progress = progress.clone();
        let metrics = metrics.clone();
        let enqueued = Instant::now();
        pool.execute(move || {
            if abort.load(Ordering::SeqCst) {
                if let Some(p) = &progress {
                    p.mark_skipped();
                }
                let _ = tx.send(Err(spec));
                return;
            }
            if let Some(m) = &metrics {
                m.dispatch_overhead.record(enqueued.elapsed());
            }
            let outcome = job(&spec);
            if fail_fast && outcome.status == TaskStatus::Failed {
                abort.store(true, Ordering::SeqCst);
            }
            if let Some(p) = &progress {
                p.mark_done();
            }
            let _ = tx.send(Ok(outcome));
        });
    }
    drop(tx);

    let mut outcomes = Vec::with_capacity(n);
    let mut skipped = Vec::new();
    for msg in rx {
        match msg {
            Ok(o) => outcomes.push(o),
            Err(spec) => skipped.push(spec),
        }
    }
    pool.join();
    let lost = n - outcomes.len() - skipped.len();
    if lost > 0 {
        eprintln!(
            "memento scheduler (unbatched): {lost} task(s) lost to unexpected \
             worker panics (pool reported {})",
            pool.panic_count()
        );
    }
    outcomes.sort_by_key(|o| o.spec.index);
    skipped.sort_by_key(|s| s.index);
    let aborted = abort.load(Ordering::SeqCst);
    if let Some(m) = &metrics {
        m.tasks_skipped.add(skipped.len() as u64);
    }
    let stats = DispatchStats {
        chunks: n,
        chunk_len: 1,
        steals: pool.stats().steals,
        local_pops: pool.stats().local_pops,
        job_panics: pool.panic_count(),
    };
    ScheduleReport { outcomes, skipped, aborted, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::pv_int;
    use crate::util::json::Json;
    use std::sync::atomic::AtomicUsize;

    fn specs(n: usize) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                params: vec![("i".to_string(), pv_int(i as i64))],
                index: i,
            })
            .collect()
    }

    fn ok_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Success,
            value: Some(Json::int(spec.index as i64)),
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    fn failed_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Failed,
            value: None,
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    #[test]
    fn executes_every_task_exactly_once() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let report = run_all(
            specs(100),
            &SchedulerOptions { workers: 8, fail_fast: false },
            Arc::new(move |s| {
                c.fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(count.load(Ordering::SeqCst), 100);
        assert_eq!(report.outcomes.len(), 100);
        assert!(report.skipped.is_empty());
        assert!(!report.aborted);
        // ordered by index
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
    }

    #[test]
    fn empty_specs() {
        let report = run_all(
            Vec::new(),
            &SchedulerOptions::default(),
            Arc::new(ok_outcome),
            None,
        );
        assert!(report.outcomes.is_empty());
        assert!(!report.aborted);
    }

    #[test]
    fn single_worker_is_sequential_and_ordered() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let o2 = Arc::clone(&order);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: false },
            Arc::new(move |s| {
                o2.lock().unwrap().push(s.index);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fail_fast_skips_remaining() {
        // 1 worker → deterministic: task 2 fails, 3.. are skipped.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 2 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert!(report.aborted);
        assert_eq!(report.outcomes.len(), 3); // 0, 1, 2
        assert_eq!(report.skipped.len(), 7);
        assert_eq!(report.skipped[0].index, 3);
    }

    #[test]
    fn fail_fast_abort_mid_chunk_skips_chunk_tail() {
        // Large n on 1 worker → chunks longer than 1 spec; a failure inside
        // a chunk must skip the *rest of that same chunk* too, not just
        // later chunks.
        let report = run_all(
            specs(200),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 10 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert!(report.aborted);
        assert_eq!(report.outcomes.len(), 11); // 0..=10
        assert_eq!(report.skipped.len(), 189);
        assert_eq!(report.skipped[0].index, 11);
        assert!(report.stats.chunk_len > 1, "test needs multi-spec chunks");
    }

    #[test]
    fn keep_going_collects_all_failures() {
        let report = run_all(
            specs(20),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(|s| {
                if s.index % 3 == 0 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 20);
        let failed = report
            .outcomes
            .iter()
            .filter(|o| o.status == TaskStatus::Failed)
            .count();
        assert_eq!(failed, 7); // 0,3,6,9,12,15,18
        assert!(!report.aborted);
    }

    #[test]
    fn progress_is_marked() {
        let progress = ProgressState::new(10);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(ok_outcome),
            Some(Arc::clone(&progress)),
        );
        assert_eq!(progress.snapshot(), (10, 10));
    }

    #[test]
    fn progress_accounts_for_skips_on_abort() {
        // Abort path: every pending spec must end up either done or
        // skipped on the progress state — the bar completes, no limbo.
        let progress = ProgressState::new(50);
        let report = run_all(
            specs(50),
            &SchedulerOptions { workers: 2, fail_fast: true },
            Arc::new(|s| {
                if s.index == 0 {
                    failed_outcome(s)
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                    ok_outcome(s)
                }
            }),
            Some(Arc::clone(&progress)),
        );
        let (done, skipped, total) = progress.snapshot_full();
        assert_eq!(done + skipped, total);
        assert_eq!(done, report.outcomes.len());
        assert_eq!(skipped, report.skipped.len());
    }

    #[test]
    fn abort_metrics_exclude_skipped_tasks() {
        // dispatch_overhead must only sample chunks that executed work;
        // tasks_skipped counts the rest. No mixing.
        let metrics = Arc::new(RunMetrics::new());
        let report = run_all_with_metrics(
            specs(300),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 0 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
            Some(Arc::clone(&metrics)),
        );
        assert!(report.aborted);
        assert_eq!(metrics.tasks_skipped.get() as usize, report.skipped.len());
        // Only the first chunk executed anything → exactly one wait sample.
        assert_eq!(metrics.dispatch_overhead.count(), 1);
        assert!(metrics.dispatch_chunks.get() > 0);
    }

    #[test]
    fn panicking_job_does_not_hang() {
        // A panic escaping `job` is a coordinator bug; the scheduler must
        // still terminate and report the remaining outcomes.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(|s| {
                if s.index == 5 {
                    panic!("coordinator bug");
                }
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 9);
        assert_eq!(report.stats.job_panics, 1);
    }

    #[test]
    fn workers_capped_at_task_count() {
        // requesting 64 workers for 2 tasks must not spawn 64 threads —
        // just verify it runs fine.
        let report = run_all(
            specs(2),
            &SchedulerOptions { workers: 64, fail_fast: false },
            Arc::new(ok_outcome),
            None,
        );
        assert_eq!(report.outcomes.len(), 2);
    }

    #[test]
    fn unbatched_reference_path_matches() {
        // The retained A/B baseline must keep the same guarantees.
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let report = run_all_unbatched(
            specs(50),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(move |s| {
                c.fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
            None,
        );
        assert_eq!(count.load(Ordering::SeqCst), 50);
        assert_eq!(report.outcomes.len(), 50);
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
    }

    // ---- stress: exactly-once at high worker counts under stealing -------

    #[test]
    fn stress_exactly_once_high_worker_count() {
        // 24 workers (well above physical cores) over 3000 uneven tasks:
        // chunks get stolen across workers and every task must still run
        // exactly once, with all outcomes collected and ordered.
        let n = 3000;
        let counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let c = Arc::clone(&counts);
        let report = run_all(
            specs(n),
            &SchedulerOptions { workers: 24, fail_fast: false },
            Arc::new(move |s| {
                // Uneven spin to force imbalance (and therefore stealing).
                let spin = (s.index % 13) * 40;
                for _ in 0..spin {
                    std::hint::black_box(s.index);
                }
                c[s.index].fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), n);
        assert!(report.skipped.is_empty());
        for (i, cnt) in counts.iter().enumerate() {
            assert_eq!(cnt.load(Ordering::SeqCst), 1, "task {i} ran != once");
        }
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
        assert!(report.stats.chunks >= 24, "stats: {:?}", report.stats);
    }

    // ---- property: exactly-once under random worker counts ---------------

    #[test]
    fn prop_exactly_once_any_worker_count() {
        use crate::testing::prop::check;
        check("scheduler-exactly-once", 25, |g| {
            let n = g.size(1, 40);
            let workers = g.size(1, 16);
            let counts: Arc<Vec<AtomicUsize>> =
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let c = Arc::clone(&counts);
            let report = run_all(
                specs(n),
                &SchedulerOptions { workers, fail_fast: false },
                Arc::new(move |s| {
                    c[s.index].fetch_add(1, Ordering::SeqCst);
                    ok_outcome(s)
                }),
                None,
            );
            crate::prop_assert!(report.outcomes.len() == n, "outcome count");
            for (i, c) in counts.iter().enumerate() {
                crate::prop_assert!(
                    c.load(Ordering::SeqCst) == 1,
                    "task {i} ran {} times",
                    c.load(Ordering::SeqCst)
                );
            }
            Ok(())
        });
    }
}
