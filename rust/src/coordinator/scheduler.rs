//! Task scheduling across the worker pool — batched, work-stealing.
//!
//! The scheduler is deliberately generic: it takes fully-formed task specs
//! and a job closure producing a [`TaskOutcome`], and guarantees
//!
//! 1. every spec is executed **exactly once** (or skipped after abort),
//! 2. worker panics *outside* the job's own catch (bugs in the coordinator
//!    itself) cannot lose outcomes silently — missing outcomes are detected
//!    and surfaced,
//! 3. fail-fast mode stops launching new tasks after the first failure
//!    while letting in-flight tasks finish; skipped specs are returned,
//!    marked on the progress bar, and **excluded** from timing metrics so
//!    abort noise never pollutes dispatch-overhead numbers.
//!
//! # Dispatch design (why this is fast *and* lazy)
//!
//! The original implementation boxed one closure per spec and cloned four
//! `Arc`s into it, then pushed every box through a single-mutex queue and
//! collected outcomes over an `mpsc` channel — five allocations plus two
//! contended queues *per task*. For 10k no-op tasks the orchestrator was
//! the workload. The second generation pre-chunked a materialized
//! `Arc<[TaskSpec]>`, which fixed per-task overhead but still required the
//! whole expansion in memory before the first task could start.
//!
//! The current core is [`run_stream`]: specs come from a **lazy iterator**
//! (typically a [`crate::coordinator::expand::Expansion`] filtered against
//! cache/checkpoint) and workers *pull* chunks from it on demand behind a
//! single mutex. Chunk granules ramp from 1 (instant first dispatch,
//! minimal first-outcome latency) up to [`STREAM_MAX_CHUNK`] (amortized
//! lock traffic in steady state), so load balancing falls out of the pull
//! discipline itself — a worker that finishes early simply pulls again.
//! Outcomes are **pushed to a callback as they complete** instead of being
//! accumulated in a `Vec`, which is what the streaming `Run` handle
//! ([`crate::coordinator::run`]) builds its live event channel on. At no
//! point does the scheduler hold more than `workers × granule` specs.
//!
//! Exactly-once follows from construction: the source mutex hands every
//! spec to exactly one puller, and each pulled spec is either executed or
//! reported skipped.
//!
//! [`run_all`]/[`run_all_with_metrics`] survive as eager adapters (tests,
//! benches, bounded workloads): they wrap a `Vec` in an iterator, collect
//! the streamed outcomes, and return the familiar [`ScheduleReport`].
//!
//! The cache/retry/checkpoint/notification pipeline around each task is
//! composed by [`crate::coordinator::memento`], keeping this module small
//! and testable in isolation.

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::progress::ProgressState;
use crate::coordinator::results::{TaskOutcome, TaskStatus};
use crate::coordinator::source::DrainOnceSource;
use crate::coordinator::task::TaskSpec;
use crate::obs::snapshot::FleetStats;
use crate::obs::trace::thread_worker_id;
use crate::util::pool::ThreadPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

// The lazy-source vocabulary lives in [`crate::coordinator::source`]; these
// re-exports keep the scheduler the conventional import site for callers
// that only speak scheduler types.
pub use crate::coordinator::source::{SpecFilter, SpecSource, ABORT_DRAIN_LIMIT};

/// Which execution tier runs the tasks.
///
/// Chosen per run via `Memento::backend` (or `--isolation` on the CLI)
/// and threaded from there through the scheduler layer:
///
/// - [`ExecBackend::Threads`] — the in-process work-stealing pool
///   ([`run_all`]). Cheapest dispatch; contains `Err`s and panics, but a
///   segfault/abort/OOM-kill in any task destroys the whole run.
/// - [`ExecBackend::Processes`] — N isolated worker *processes* driven by
///   [`crate::ipc::supervisor`]. A dying worker costs one attempt of one
///   task: the supervisor requeues it under the run's `RetryPolicy` and
///   respawns the worker, up to `crash_budget` respawns per slot.
/// - [`ExecBackend::Remote`] — the distributed tier: the supervisor
///   listens on TCP and leases **standing workers** (`memento serve`
///   processes, on this machine or others) from a
///   [`crate::ipc::pool::WorkerPool`] instead of spawning them. Same
///   exactly-once accounting as `Processes`, plus shared-token auth,
///   reconnect-with-backoff for dropped workers, and an optional
///   per-task wall-clock budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecBackend {
    /// In-process worker threads (the default).
    Threads,
    /// Isolated worker processes over the std-only IPC protocol.
    Processes {
        /// Worker processes to run concurrently.
        workers: usize,
        /// Worker respawns allowed per slot before it retires.
        crash_budget: u32,
    },
    /// Standing remote workers leased over TCP (see [`crate::ipc::pool`]).
    Remote {
        /// Bind address for the worker-registration listener, e.g.
        /// `"0.0.0.0:7070"` (or `"127.0.0.1:0"` for an OS-assigned
        /// loopback port). Ignored when the run is given an existing pool
        /// via `Memento::with_worker_pool` — the standing pool's listener
        /// is used instead.
        addr: String,
        /// Concurrent worker leases (max task attempts in flight).
        workers: usize,
        /// Per-task wall-clock budget for this backend; `None` falls back
        /// to `Memento::task_timeout` (and `None` there means unbounded).
        task_timeout: Option<std::time::Duration>,
    },
}

impl Default for ExecBackend {
    fn default() -> Self {
        ExecBackend::Threads
    }
}

/// Scheduling configuration.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Worker threads. Defaults to the machine's logical CPU count.
    pub workers: usize,
    /// Stop dispatching after the first failed task.
    pub fail_fast: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { workers: crate::util::pool::num_cpus(), fail_fast: false }
    }
}

/// Load-balance evidence for one [`run_stream`]/[`run_all`] invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct DispatchStats {
    /// Chunk pulls taken from the spec source.
    pub chunks: usize,
    /// Largest granule pulled (pulls ramp 1 → [`STREAM_MAX_CHUNK`]).
    pub chunk_len: usize,
    /// Chunks a worker took from a sibling's queue.
    pub steals: usize,
    /// Chunks a worker took from its own queue.
    pub local_pops: usize,
    /// Jobs whose `job` closure panicked (coordinator bugs; outcome lost).
    pub job_panics: usize,
}

/// What happened to each dispatched spec.
pub struct ScheduleReport {
    /// Outcomes for tasks that ran (or were restored); ordered by spec index.
    pub outcomes: Vec<TaskOutcome>,
    /// Specs skipped because fail-fast aborted the run.
    pub skipped: Vec<TaskSpec>,
    /// True if fail-fast triggered.
    pub aborted: bool,
    /// Dispatch/steal counters for this run.
    pub stats: DispatchStats,
}

/// The executing closure: spec in, terminal outcome out.
pub type Job = Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>;

/// Largest granule a worker pulls from the source in one lock
/// acquisition. Granules ramp 1 → 2 → 4 → … → this cap per worker, so the
/// first outcome is dispatched after a single pull of one spec.
pub const STREAM_MAX_CHUNK: usize = 64;

/// Streaming callbacks for [`run_stream`]. Everything is optional; a bare
/// `StreamHooks::default()` runs the stream for its side effects only.
#[derive(Default)]
#[allow(clippy::type_complexity)]
pub struct StreamHooks {
    /// Receives every terminal outcome the moment it completes, from the
    /// executing worker's thread. This replaces the accumulated `Vec`.
    pub on_outcome: Option<Arc<dyn Fn(TaskOutcome) + Send + Sync>>,
    /// Receives every spec abandoned after a fail-fast abort.
    pub on_skip: Option<Arc<dyn Fn(TaskSpec) + Send + Sync>>,
    /// The planner's restore stage: maps each raw spec to `Some` (still
    /// pending) or `None` (restored from cache/checkpoint, delivered out
    /// of band). Runs on the pulling worker's thread **outside** the
    /// source mutex — see [`DrainOnceSource`] — so restores parallelize
    /// across workers.
    pub restore_filter: Option<SpecFilter>,
    /// Fires exactly once, when the source iterator is exhausted and all
    /// pulled specs have cleared the restore filter (also during the
    /// post-abort drain). The streaming run layer uses it to finalize
    /// totals and release the `RunStarted` notification.
    pub on_source_drained: Option<Box<dyn FnOnce() + Send + Sync>>,
    /// Live progress counters (planned/done/skipped totals).
    pub progress: Option<Arc<ProgressState>>,
    /// Shared metrics registry (dispatch counters, timers).
    pub metrics: Option<Arc<RunMetrics>>,
    /// Cooperative cancellation: once set, workers stop pulling, in-flight
    /// tasks finish, and the remaining source is *not* drained (a cancel
    /// must return promptly even on a 10¹²-combination matrix).
    pub cancel: Option<Arc<AtomicBool>>,
    /// Live per-worker stats feeding telemetry snapshots: each pull loop
    /// reports a liveness touch per chunk and a completion per executed
    /// task, keyed by its [`thread_worker_id`].
    pub fleet: Option<Arc<FleetStats>>,
}

/// What happened across one [`run_stream`] invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamReport {
    /// Outcomes delivered to `on_outcome` (executed tasks).
    pub executed: usize,
    /// Specs reported to `on_skip` after an abort.
    pub skipped: usize,
    /// True if fail-fast triggered.
    pub aborted: bool,
    /// True if the cancel flag stopped the run.
    pub cancelled: bool,
    /// True when the post-abort skip drain hit [`ABORT_DRAIN_LIMIT`]
    /// before exhausting the source: `skipped` is then a lower bound.
    pub drain_truncated: bool,
    /// Pull/steal counters for this run.
    pub stats: DispatchStats,
}

/// Everything a pull-loop worker needs, shared once.
struct StreamCtx {
    source: DrainOnceSource,
    job: Job,
    abort: AtomicBool,
    fail_fast: bool,
    cancel: Option<Arc<AtomicBool>>,
    on_outcome: Option<Arc<dyn Fn(TaskOutcome) + Send + Sync>>,
    on_skip: Option<Arc<dyn Fn(TaskSpec) + Send + Sync>>,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
    fleet: Option<Arc<FleetStats>>,
    executed: AtomicUsize,
    skipped: AtomicUsize,
    pulls: AtomicUsize,
    max_granule: AtomicUsize,
    job_panics: AtomicUsize,
}

impl StreamCtx {
    fn cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .map(|c| c.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    fn stopped(&self) -> bool {
        self.abort.load(Ordering::SeqCst) || self.cancelled()
    }

    fn skip(&self, spec: TaskSpec) {
        self.skipped.fetch_add(1, Ordering::SeqCst);
        if let Some(p) = &self.progress {
            p.mark_skipped();
        }
        if let Some(cb) = &self.on_skip {
            cb(spec);
        }
    }
}

/// One pool worker's pull loop.
fn stream_worker(ctx: &StreamCtx) {
    let mut granule = 1usize;
    let worker = thread_worker_id();
    loop {
        if ctx.stopped() {
            return;
        }
        let pulled_at = Instant::now();
        let chunk = ctx.source.pull(granule);
        if chunk.is_empty() {
            return;
        }
        if let Some(f) = &ctx.fleet {
            // A chunk pickup is this backend's liveness signal (there is
            // no heartbeat frame between threads in one process).
            f.heartbeat(worker);
        }
        ctx.pulls.fetch_add(1, Ordering::SeqCst);
        ctx.max_granule.fetch_max(chunk.len(), Ordering::SeqCst);
        let mut sampled = false;
        for spec in chunk {
            if ctx.stopped() {
                // Abort raced in mid-chunk: the rest of this granule is
                // skipped work, not lost work.
                ctx.skip(spec);
                continue;
            }
            if !sampled {
                sampled = true;
                // One dispatch-cost sample per chunk that executes work
                // (lock acquisition + lazy-expansion pull + this worker's
                // share of restore filtering); skipped specs stay out of
                // the timer.
                if let Some(m) = &ctx.metrics {
                    m.dispatch_overhead.record(pulled_at.elapsed());
                }
            }
            match catch_unwind(AssertUnwindSafe(|| (ctx.job)(&spec))) {
                Ok(outcome) => {
                    if ctx.fail_fast && outcome.status == TaskStatus::Failed {
                        ctx.abort.store(true, Ordering::SeqCst);
                    }
                    if let Some(p) = &ctx.progress {
                        p.mark_done();
                    }
                    if let Some(f) = &ctx.fleet {
                        f.task_completed(worker);
                    }
                    ctx.executed.fetch_add(1, Ordering::SeqCst);
                    if let Some(cb) = &ctx.on_outcome {
                        cb(outcome);
                    }
                }
                Err(_) => {
                    // Panic escaping `job` — contained so the rest of the
                    // stream still completes; counted and surfaced by the
                    // caller.
                    ctx.job_panics.fetch_add(1, Ordering::SeqCst);
                    if let Some(p) = &ctx.progress {
                        p.mark_done();
                    }
                }
            }
        }
        granule = (granule * 2).min(STREAM_MAX_CHUNK);
    }
}

/// The streaming core: runs `job` over every spec the lazy `source`
/// yields, on `opts.workers` pull-loop threads, pushing each outcome
/// through `hooks.on_outcome` the moment it completes.
///
/// Guarantees:
/// 1. every yielded spec is executed **exactly once**, or reported via
///    `on_skip` after a fail-fast abort (cancelled runs stop consuming
///    the source instead);
/// 2. the source is never materialized — peak held specs are
///    `workers × STREAM_MAX_CHUNK`;
/// 3. a panic escaping `job` is contained per-task and counted in
///    [`DispatchStats::job_panics`].
pub fn run_stream(
    source: SpecSource,
    opts: &SchedulerOptions,
    job: Job,
    hooks: StreamHooks,
) -> StreamReport {
    let workers = opts.workers.max(1);
    let metrics = hooks.metrics.clone();
    let ctx = Arc::new(StreamCtx {
        source: DrainOnceSource::new(source, hooks.restore_filter, hooks.on_source_drained),
        job,
        abort: AtomicBool::new(false),
        fail_fast: opts.fail_fast,
        cancel: hooks.cancel,
        on_outcome: hooks.on_outcome,
        on_skip: hooks.on_skip,
        progress: hooks.progress,
        metrics: hooks.metrics,
        fleet: hooks.fleet,
        executed: AtomicUsize::new(0),
        skipped: AtomicUsize::new(0),
        pulls: AtomicUsize::new(0),
        max_granule: AtomicUsize::new(0),
        job_panics: AtomicUsize::new(0),
    });

    let pool = ThreadPool::new(workers);
    let jobs: Vec<_> = (0..workers)
        .map(|_| {
            let ctx = Arc::clone(&ctx);
            move || stream_worker(&ctx)
        })
        .collect();
    pool.execute_batch(jobs);
    pool.join();
    let pool_stats = pool.stats();
    drop(pool);

    let aborted = ctx.abort.load(Ordering::SeqCst);
    let cancelled = ctx.cancelled();
    let mut drain_truncated = false;
    if aborted && !cancelled {
        // Account for the work the abort left behind: drain the rest of
        // the source as skipped specs so every included task is either an
        // outcome or a skip. The drain is bounded by ABORT_DRAIN_LIMIT
        // (fail-fast must return promptly even on an astronomically large
        // matrix; the remainder stays un-enumerated and is flagged as
        // truncated) and restorable specs still restore through the
        // filter. Cancelled runs skip the drain entirely.
        let report = ctx
            .source
            .drain(ABORT_DRAIN_LIMIT, &mut |spec| ctx.skip(spec), &|| ctx.cancelled());
        drain_truncated = report.truncated;
    }

    let stats = DispatchStats {
        chunks: ctx.pulls.load(Ordering::SeqCst),
        chunk_len: ctx.max_granule.load(Ordering::SeqCst),
        steals: pool_stats.steals,
        local_pops: pool_stats.local_pops,
        job_panics: ctx.job_panics.load(Ordering::SeqCst),
    };
    let report = StreamReport {
        executed: ctx.executed.load(Ordering::SeqCst),
        skipped: ctx.skipped.load(Ordering::SeqCst),
        aborted,
        cancelled: ctx.cancelled(),
        drain_truncated,
        stats,
    };
    if let Some(m) = &metrics {
        m.dispatch_chunks.add(stats.chunks as u64);
        m.steals.add(stats.steals as u64);
        m.tasks_skipped.add(report.skipped as u64);
    }
    report
}

/// Runs `job` over all `specs` on a pool of `opts.workers` threads.
///
/// `job` must itself be panic-safe (it converts experiment panics into
/// failed outcomes); a panic escaping `job` is a coordinator bug and is
/// contained per-task, counted in [`DispatchStats::job_panics`], and
/// surfaced loudly — the run still accounts for every other task.
pub fn run_all(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Job,
    progress: Option<Arc<ProgressState>>,
) -> ScheduleReport {
    run_all_with_metrics(specs, opts, job, progress, None)
}

/// [`run_all`] with a metrics registry: records per-chunk dispatch cost
/// into `dispatch_overhead`, plus steal/skip counters at the end of the
/// run. Skipped (fail-fast) specs never contribute dispatch samples.
///
/// This is the eager adapter over [`run_stream`]: it wraps the `Vec` in an
/// iterator, collects the streamed outcomes, and returns them ordered by
/// spec index.
pub fn run_all_with_metrics(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Job,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
) -> ScheduleReport {
    let n = specs.len();
    if n == 0 {
        return ScheduleReport {
            outcomes: Vec::new(),
            skipped: Vec::new(),
            aborted: false,
            stats: DispatchStats::default(),
        };
    }
    let outcomes = Arc::new(Mutex::new(Vec::with_capacity(n)));
    let skipped = Arc::new(Mutex::new(Vec::new()));
    let sched = SchedulerOptions {
        workers: opts.workers.max(1).min(n),
        fail_fast: opts.fail_fast,
    };
    let report = run_stream(
        Box::new(specs.into_iter()),
        &sched,
        job,
        StreamHooks {
            on_outcome: Some({
                let outcomes = Arc::clone(&outcomes);
                Arc::new(move |o: TaskOutcome| outcomes.lock().unwrap().push(o))
            }),
            on_skip: Some({
                let skipped = Arc::clone(&skipped);
                Arc::new(move |s: TaskSpec| skipped.lock().unwrap().push(s))
            }),
            progress,
            metrics,
            ..StreamHooks::default()
        },
    );
    let mut outcomes = std::mem::take(&mut *outcomes.lock().unwrap());
    let mut skipped = std::mem::take(&mut *skipped.lock().unwrap());
    let lost = n - outcomes.len() - skipped.len();
    if report.drain_truncated {
        // Not lost work: the fail-fast skip drain stopped at
        // ABORT_DRAIN_LIMIT, so the tail of this (very large) spec list
        // is simply un-enumerated.
        eprintln!(
            "memento scheduler: fail-fast abort; {} spec(s) skipped, \
             {lost} more not enumerated (drain limit {ABORT_DRAIN_LIMIT})",
            skipped.len()
        );
    } else if lost > 0 {
        // Coordinator-level bug: account for it loudly rather than silently.
        eprintln!(
            "memento scheduler: {lost} task(s) lost to unexpected job panics \
             ({} contained)",
            report.stats.job_panics
        );
    }
    outcomes.sort_by_key(|o| o.spec.index);
    skipped.sort_by_key(|s| s.index);

    ScheduleReport {
        outcomes,
        skipped,
        aborted: report.aborted,
        stats: report.stats,
    }
}

/// The pre-batching reference implementation: one boxed closure, four Arc
/// clones, and one channel send **per task**.
///
/// Note what this baseline does and does not reproduce: it submits through
/// the *current* work-stealing pool (the old single-`Mutex<VecDeque>` pool
/// no longer exists in the build), so an A/B against [`run_all`] isolates
/// the **per-task boxing + Arc + channel overhead vs chunked dispatch** —
/// it does *not* include the old central-queue contention, which was
/// removed for both paths by the pool rewrite. Treat recorded speedups as
/// a lower bound on the full improvement over the seed design.
///
/// Semantically equivalent to [`run_all`] (exactly-once, fail-fast,
/// panic containment) and retained so `benches/scheduler.rs` can measure
/// the dispatch-overhead delta on the same build — the before/after
/// evidence in `BENCH_sched_cache.json`.
pub fn run_all_unbatched(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
) -> ScheduleReport {
    let n = specs.len();
    if n == 0 {
        return ScheduleReport {
            outcomes: Vec::new(),
            skipped: Vec::new(),
            aborted: false,
            stats: DispatchStats::default(),
        };
    }
    let workers = opts.workers.max(1).min(n);
    let pool = ThreadPool::new(workers);
    let (tx, rx) = mpsc::channel::<Result<TaskOutcome, TaskSpec>>();
    let abort = Arc::new(AtomicBool::new(false));
    let fail_fast = opts.fail_fast;

    for spec in specs {
        let tx = tx.clone();
        let job = Arc::clone(&job);
        let abort = Arc::clone(&abort);
        let progress = progress.clone();
        let metrics = metrics.clone();
        let enqueued = Instant::now();
        pool.execute(move || {
            if abort.load(Ordering::SeqCst) {
                if let Some(p) = &progress {
                    p.mark_skipped();
                }
                let _ = tx.send(Err(spec));
                return;
            }
            if let Some(m) = &metrics {
                m.dispatch_overhead.record(enqueued.elapsed());
            }
            let outcome = job(&spec);
            if fail_fast && outcome.status == TaskStatus::Failed {
                abort.store(true, Ordering::SeqCst);
            }
            if let Some(p) = &progress {
                p.mark_done();
            }
            let _ = tx.send(Ok(outcome));
        });
    }
    drop(tx);

    let mut outcomes = Vec::with_capacity(n);
    let mut skipped = Vec::new();
    for msg in rx {
        match msg {
            Ok(o) => outcomes.push(o),
            Err(spec) => skipped.push(spec),
        }
    }
    pool.join();
    let lost = n - outcomes.len() - skipped.len();
    if lost > 0 {
        eprintln!(
            "memento scheduler (unbatched): {lost} task(s) lost to unexpected \
             worker panics (pool reported {})",
            pool.panic_count()
        );
    }
    outcomes.sort_by_key(|o| o.spec.index);
    skipped.sort_by_key(|s| s.index);
    let aborted = abort.load(Ordering::SeqCst);
    if let Some(m) = &metrics {
        m.tasks_skipped.add(skipped.len() as u64);
    }
    let stats = DispatchStats {
        chunks: n,
        chunk_len: 1,
        steals: pool.stats().steals,
        local_pops: pool.stats().local_pops,
        job_panics: pool.panic_count(),
    };
    ScheduleReport { outcomes, skipped, aborted, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::pv_int;
    use crate::util::json::Json;
    use std::sync::atomic::AtomicUsize;

    fn specs(n: usize) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                params: vec![("i".to_string(), pv_int(i as i64))],
                index: i,
                exp: None,
            })
            .collect()
    }

    fn ok_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Success,
            value: Some(Json::int(spec.index as i64)),
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    fn failed_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Failed,
            value: None,
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    #[test]
    fn executes_every_task_exactly_once() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let report = run_all(
            specs(100),
            &SchedulerOptions { workers: 8, fail_fast: false },
            Arc::new(move |s| {
                c.fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(count.load(Ordering::SeqCst), 100);
        assert_eq!(report.outcomes.len(), 100);
        assert!(report.skipped.is_empty());
        assert!(!report.aborted);
        // ordered by index
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
    }

    #[test]
    fn empty_specs() {
        let report = run_all(
            Vec::new(),
            &SchedulerOptions::default(),
            Arc::new(ok_outcome),
            None,
        );
        assert!(report.outcomes.is_empty());
        assert!(!report.aborted);
    }

    #[test]
    fn single_worker_is_sequential_and_ordered() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let o2 = Arc::clone(&order);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: false },
            Arc::new(move |s| {
                o2.lock().unwrap().push(s.index);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fail_fast_skips_remaining() {
        // 1 worker → deterministic: task 2 fails, 3.. are skipped.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 2 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert!(report.aborted);
        assert_eq!(report.outcomes.len(), 3); // 0, 1, 2
        assert_eq!(report.skipped.len(), 7);
        assert_eq!(report.skipped[0].index, 3);
    }

    #[test]
    fn fail_fast_abort_mid_chunk_skips_chunk_tail() {
        // Large n on 1 worker → chunks longer than 1 spec; a failure inside
        // a chunk must skip the *rest of that same chunk* too, not just
        // later chunks.
        let report = run_all(
            specs(200),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 10 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert!(report.aborted);
        assert_eq!(report.outcomes.len(), 11); // 0..=10
        assert_eq!(report.skipped.len(), 189);
        assert_eq!(report.skipped[0].index, 11);
        assert!(report.stats.chunk_len > 1, "test needs multi-spec chunks");
    }

    #[test]
    fn keep_going_collects_all_failures() {
        let report = run_all(
            specs(20),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(|s| {
                if s.index % 3 == 0 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 20);
        let failed = report
            .outcomes
            .iter()
            .filter(|o| o.status == TaskStatus::Failed)
            .count();
        assert_eq!(failed, 7); // 0,3,6,9,12,15,18
        assert!(!report.aborted);
    }

    #[test]
    fn progress_is_marked() {
        let progress = ProgressState::new(10);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(ok_outcome),
            Some(Arc::clone(&progress)),
        );
        assert_eq!(progress.snapshot(), (10, 10));
    }

    #[test]
    fn progress_accounts_for_skips_on_abort() {
        // Abort path: every pending spec must end up either done or
        // skipped on the progress state — the bar completes, no limbo.
        let progress = ProgressState::new(50);
        let report = run_all(
            specs(50),
            &SchedulerOptions { workers: 2, fail_fast: true },
            Arc::new(|s| {
                if s.index == 0 {
                    failed_outcome(s)
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                    ok_outcome(s)
                }
            }),
            Some(Arc::clone(&progress)),
        );
        let (done, skipped, total) = progress.snapshot_full();
        assert_eq!(done + skipped, total);
        assert_eq!(done, report.outcomes.len());
        assert_eq!(skipped, report.skipped.len());
    }

    #[test]
    fn abort_metrics_exclude_skipped_tasks() {
        // dispatch_overhead must only sample chunks that executed work;
        // tasks_skipped counts the rest. No mixing.
        let metrics = Arc::new(RunMetrics::new());
        let report = run_all_with_metrics(
            specs(300),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 0 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
            Some(Arc::clone(&metrics)),
        );
        assert!(report.aborted);
        assert_eq!(metrics.tasks_skipped.get() as usize, report.skipped.len());
        // Only the first chunk executed anything → exactly one wait sample.
        assert_eq!(metrics.dispatch_overhead.count(), 1);
        assert!(metrics.dispatch_chunks.get() > 0);
    }

    #[test]
    fn panicking_job_does_not_hang() {
        // A panic escaping `job` is a coordinator bug; the scheduler must
        // still terminate and report the remaining outcomes.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(|s| {
                if s.index == 5 {
                    panic!("coordinator bug");
                }
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 9);
        assert_eq!(report.stats.job_panics, 1);
    }

    #[test]
    fn workers_capped_at_task_count() {
        // requesting 64 workers for 2 tasks must not spawn 64 threads —
        // just verify it runs fine.
        let report = run_all(
            specs(2),
            &SchedulerOptions { workers: 64, fail_fast: false },
            Arc::new(ok_outcome),
            None,
        );
        assert_eq!(report.outcomes.len(), 2);
    }

    #[test]
    fn unbatched_reference_path_matches() {
        // The retained A/B baseline must keep the same guarantees.
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let report = run_all_unbatched(
            specs(50),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(move |s| {
                c.fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
            None,
        );
        assert_eq!(count.load(Ordering::SeqCst), 50);
        assert_eq!(report.outcomes.len(), 50);
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
    }

    // ---- streaming core ---------------------------------------------------

    #[test]
    fn stream_pushes_outcomes_without_accumulating() {
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let s2 = Arc::clone(&seen);
        let drained = Arc::new(AtomicBool::new(false));
        let d2 = Arc::clone(&drained);
        let report = run_stream(
            Box::new(specs(40).into_iter()),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(ok_outcome),
            StreamHooks {
                on_outcome: Some(Arc::new(move |o: TaskOutcome| {
                    s2.lock().unwrap().push(o.spec.index)
                })),
                on_source_drained: Some(Box::new(move || {
                    d2.store(true, Ordering::SeqCst);
                })),
                ..StreamHooks::default()
            },
        );
        assert_eq!(report.executed, 40);
        assert_eq!(report.skipped, 0);
        assert!(!report.aborted && !report.cancelled);
        assert!(drained.load(Ordering::SeqCst), "on_source_drained fired");
        let mut idx = std::mem::take(&mut *seen.lock().unwrap());
        idx.sort_unstable();
        assert_eq!(idx, (0..40).collect::<Vec<_>>());
        assert!(report.stats.chunks > 0);
    }

    #[test]
    fn stream_is_lazy_first_pull_is_one_spec() {
        // The source records how far it was consumed; with one worker the
        // first task must execute after exactly one spec was pulled
        // (granule ramp starts at 1), never after a full materialization.
        let consumed = Arc::new(AtomicUsize::new(0));
        let consumed_at_first_exec = Arc::new(AtomicUsize::new(usize::MAX));
        let c2 = Arc::clone(&consumed);
        let source = (0..10_000).map(move |i| {
            c2.fetch_add(1, Ordering::SeqCst);
            TaskSpec { params: vec![("i".to_string(), pv_int(i as i64))], index: i, exp: None }
        });
        let c3 = Arc::clone(&consumed);
        let cafe = Arc::clone(&consumed_at_first_exec);
        run_stream(
            Box::new(source),
            &SchedulerOptions { workers: 1, fail_fast: false },
            Arc::new(move |s| {
                let _ = cafe.compare_exchange(
                    usize::MAX,
                    c3.load(Ordering::SeqCst),
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                );
                ok_outcome(s)
            }),
            StreamHooks::default(),
        );
        assert_eq!(consumed.load(Ordering::SeqCst), 10_000, "all specs ran");
        assert_eq!(
            consumed_at_first_exec.load(Ordering::SeqCst),
            1,
            "first execution must happen after pulling exactly one spec"
        );
    }

    #[test]
    fn stream_cancel_stops_pulling_and_returns_promptly() {
        // Cancelling mid-flight: in-flight work finishes, the source is
        // not consumed further (no multi-hour drain on huge matrices).
        let cancel = Arc::new(AtomicBool::new(false));
        let c2 = Arc::clone(&cancel);
        let executed = Arc::new(AtomicUsize::new(0));
        let e2 = Arc::clone(&executed);
        let report = run_stream(
            Box::new(specs(100_000).into_iter()),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(move |s| {
                if e2.fetch_add(1, Ordering::SeqCst) == 4 {
                    c2.store(true, Ordering::SeqCst);
                }
                ok_outcome(s)
            }),
            StreamHooks { cancel: Some(Arc::clone(&cancel)), ..StreamHooks::default() },
        );
        assert!(report.cancelled);
        assert!(!report.aborted);
        assert!(report.executed >= 5, "executed {}", report.executed);
        // Already-pulled chunk tails are accounted as skips, but the bulk
        // of the source is simply never consumed.
        assert!(
            report.executed + report.skipped < 1000,
            "executed {} + skipped {} — cancel did not stop the stream",
            report.executed,
            report.skipped
        );
    }

    #[test]
    fn stream_abort_drains_source_as_skips() {
        let skipped = Arc::new(AtomicUsize::new(0));
        let s2 = Arc::clone(&skipped);
        let report = run_stream(
            Box::new(specs(500).into_iter()),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 3 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            StreamHooks {
                on_skip: Some(Arc::new(move |_: TaskSpec| {
                    s2.fetch_add(1, Ordering::SeqCst);
                })),
                ..StreamHooks::default()
            },
        );
        assert!(report.aborted);
        assert_eq!(report.executed + report.skipped, 500, "exact accounting");
        assert_eq!(skipped.load(Ordering::SeqCst), report.skipped);
    }

    // ---- stress: exactly-once at high worker counts under stealing -------

    #[test]
    fn stress_exactly_once_high_worker_count() {
        // 24 workers (well above physical cores) over 3000 uneven tasks:
        // chunks get stolen across workers and every task must still run
        // exactly once, with all outcomes collected and ordered.
        let n = 3000;
        let counts: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let c = Arc::clone(&counts);
        let report = run_all(
            specs(n),
            &SchedulerOptions { workers: 24, fail_fast: false },
            Arc::new(move |s| {
                // Uneven spin to force imbalance (and therefore stealing).
                let spin = (s.index % 13) * 40;
                for _ in 0..spin {
                    std::hint::black_box(s.index);
                }
                c[s.index].fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), n);
        assert!(report.skipped.is_empty());
        for (i, cnt) in counts.iter().enumerate() {
            assert_eq!(cnt.load(Ordering::SeqCst), 1, "task {i} ran != once");
        }
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
        assert!(report.stats.chunks >= 24, "stats: {:?}", report.stats);
    }

    // ---- property: exactly-once under random worker counts ---------------

    #[test]
    fn prop_exactly_once_any_worker_count() {
        use crate::testing::prop::check;
        check("scheduler-exactly-once", 25, |g| {
            let n = g.size(1, 40);
            let workers = g.size(1, 16);
            let counts: Arc<Vec<AtomicUsize>> =
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let c = Arc::clone(&counts);
            let report = run_all(
                specs(n),
                &SchedulerOptions { workers, fail_fast: false },
                Arc::new(move |s| {
                    c[s.index].fetch_add(1, Ordering::SeqCst);
                    ok_outcome(s)
                }),
                None,
            );
            crate::prop_assert!(report.outcomes.len() == n, "outcome count");
            for (i, c) in counts.iter().enumerate() {
                crate::prop_assert!(
                    c.load(Ordering::SeqCst) == 1,
                    "task {i} ran {} times",
                    c.load(Ordering::SeqCst)
                );
            }
            Ok(())
        });
    }
}
