//! Task scheduling across the worker pool.
//!
//! The scheduler is deliberately generic: it takes fully-formed task specs
//! and a job closure producing a [`TaskOutcome`], and guarantees
//!
//! 1. every spec is executed **exactly once** (or skipped after abort),
//! 2. worker panics *outside* the job's own catch (bugs in the coordinator
//!    itself) cannot lose outcomes silently — missing outcomes are detected
//!    and surfaced,
//! 3. fail-fast mode stops dispatching new tasks after the first failure
//!    while letting in-flight tasks finish.
//!
//! The cache/retry/checkpoint/notification pipeline around each task is
//! composed by [`crate::coordinator::memento`], keeping this module small
//! and testable in isolation.

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::progress::ProgressState;
use crate::coordinator::results::{TaskOutcome, TaskStatus};
use crate::coordinator::task::TaskSpec;
use crate::util::pool::ThreadPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Scheduling configuration.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Worker threads. Defaults to the machine's logical CPU count.
    pub workers: usize,
    /// Stop dispatching after the first failed task.
    pub fail_fast: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { workers: crate::util::pool::num_cpus(), fail_fast: false }
    }
}

/// What happened to each dispatched spec.
pub struct ScheduleReport {
    /// Outcomes for tasks that ran (or were restored); ordered by spec index.
    pub outcomes: Vec<TaskOutcome>,
    /// Specs skipped because fail-fast aborted the run.
    pub skipped: Vec<TaskSpec>,
    /// True if fail-fast triggered.
    pub aborted: bool,
}

/// Runs `job` over all `specs` on a pool of `opts.workers` threads.
///
/// `job` must itself be panic-safe (it converts experiment panics into
/// failed outcomes); a panic escaping `job` is a coordinator bug and is
/// reported as a synthesized failed outcome so the run still accounts for
/// every task.
pub fn run_all(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
) -> ScheduleReport {
    run_all_with_metrics(specs, opts, job, progress, None)
}

/// [`run_all`] with a metrics registry: records per-task queue wait
/// (enqueue → job start) into `dispatch_overhead`.
pub fn run_all_with_metrics(
    specs: Vec<TaskSpec>,
    opts: &SchedulerOptions,
    job: Arc<dyn Fn(&TaskSpec) -> TaskOutcome + Send + Sync>,
    progress: Option<Arc<ProgressState>>,
    metrics: Option<Arc<RunMetrics>>,
) -> ScheduleReport {
    let n = specs.len();
    if n == 0 {
        return ScheduleReport { outcomes: Vec::new(), skipped: Vec::new(), aborted: false };
    }
    let workers = opts.workers.max(1).min(n.max(1));
    let pool = ThreadPool::new(workers);
    let (tx, rx) = mpsc::channel::<Result<TaskOutcome, TaskSpec>>();
    let abort = Arc::new(AtomicBool::new(false));
    let fail_fast = opts.fail_fast;

    for spec in specs {
        let tx = tx.clone();
        let job = Arc::clone(&job);
        let abort = Arc::clone(&abort);
        let progress = progress.clone();
        let metrics = metrics.clone();
        let enqueued = Instant::now();
        pool.execute(move || {
            if abort.load(Ordering::SeqCst) {
                let _ = tx.send(Err(spec));
                return;
            }
            if let Some(m) = &metrics {
                m.dispatch_overhead.record(enqueued.elapsed());
            }
            let outcome = job(&spec);
            if fail_fast && outcome.status == TaskStatus::Failed {
                abort.store(true, Ordering::SeqCst);
            }
            if let Some(p) = &progress {
                p.mark_done();
            }
            let _ = tx.send(Ok(outcome));
        });
    }
    drop(tx);

    let mut outcomes = Vec::with_capacity(n);
    let mut skipped = Vec::new();
    // Collect until all senders hang up. Jobs that panicked *around* the
    // job closure never send; the pool contains the panic, the sender is
    // dropped, and the channel closes once all jobs end — the count check
    // below surfaces the loss.
    for msg in rx {
        match msg {
            Ok(o) => outcomes.push(o),
            Err(spec) => skipped.push(spec),
        }
    }
    pool.join();

    let lost = n - outcomes.len() - skipped.len();
    if lost > 0 {
        // Coordinator-level bug: account for it loudly rather than silently.
        eprintln!(
            "memento scheduler: {lost} task(s) lost to unexpected worker panics \
             (pool reported {})",
            pool.panic_count()
        );
    }
    outcomes.sort_by_key(|o| o.spec.index);
    skipped.sort_by_key(|s| s.index);
    let aborted = abort.load(Ordering::SeqCst);
    ScheduleReport { outcomes, skipped, aborted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::pv_int;
    use crate::util::json::Json;
    use std::sync::atomic::AtomicUsize;

    fn specs(n: usize) -> Vec<TaskSpec> {
        (0..n)
            .map(|i| TaskSpec {
                params: vec![("i".to_string(), pv_int(i as i64))],
                index: i,
            })
            .collect()
    }

    fn ok_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Success,
            value: Some(Json::int(spec.index as i64)),
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    fn failed_outcome(spec: &TaskSpec) -> TaskOutcome {
        TaskOutcome {
            spec: spec.clone(),
            id: spec.id("v1"),
            status: TaskStatus::Failed,
            value: None,
            failure: None,
            duration_secs: 0.0,
            from_cache: false,
            attempts: 1,
        }
    }

    #[test]
    fn executes_every_task_exactly_once() {
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let report = run_all(
            specs(100),
            &SchedulerOptions { workers: 8, fail_fast: false },
            Arc::new(move |s| {
                c.fetch_add(1, Ordering::SeqCst);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(count.load(Ordering::SeqCst), 100);
        assert_eq!(report.outcomes.len(), 100);
        assert!(report.skipped.is_empty());
        assert!(!report.aborted);
        // ordered by index
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.spec.index, i);
        }
    }

    #[test]
    fn empty_specs() {
        let report = run_all(
            Vec::new(),
            &SchedulerOptions::default(),
            Arc::new(ok_outcome),
            None,
        );
        assert!(report.outcomes.is_empty());
        assert!(!report.aborted);
    }

    #[test]
    fn single_worker_is_sequential_and_ordered() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let o2 = Arc::clone(&order);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: false },
            Arc::new(move |s| {
                o2.lock().unwrap().push(s.index);
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fail_fast_skips_remaining() {
        // 1 worker → deterministic: task 2 fails, 3.. are skipped.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 1, fail_fast: true },
            Arc::new(|s| {
                if s.index == 2 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert!(report.aborted);
        assert_eq!(report.outcomes.len(), 3); // 0, 1, 2
        assert_eq!(report.skipped.len(), 7);
        assert_eq!(report.skipped[0].index, 3);
    }

    #[test]
    fn keep_going_collects_all_failures() {
        let report = run_all(
            specs(20),
            &SchedulerOptions { workers: 4, fail_fast: false },
            Arc::new(|s| {
                if s.index % 3 == 0 {
                    failed_outcome(s)
                } else {
                    ok_outcome(s)
                }
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 20);
        let failed = report
            .outcomes
            .iter()
            .filter(|o| o.status == TaskStatus::Failed)
            .count();
        assert_eq!(failed, 7); // 0,3,6,9,12,15,18
        assert!(!report.aborted);
    }

    #[test]
    fn progress_is_marked() {
        let progress = ProgressState::new(10);
        run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(ok_outcome),
            Some(Arc::clone(&progress)),
        );
        assert_eq!(progress.snapshot(), (10, 10));
    }

    #[test]
    fn panicking_job_does_not_hang() {
        // A panic escaping `job` is a coordinator bug; the scheduler must
        // still terminate and report the remaining outcomes.
        let report = run_all(
            specs(10),
            &SchedulerOptions { workers: 2, fail_fast: false },
            Arc::new(|s| {
                if s.index == 5 {
                    panic!("coordinator bug");
                }
                ok_outcome(s)
            }),
            None,
        );
        assert_eq!(report.outcomes.len(), 9);
    }

    #[test]
    fn workers_capped_at_task_count() {
        // requesting 64 workers for 2 tasks must not spawn 64 threads —
        // just verify it runs fine.
        let report = run_all(
            specs(2),
            &SchedulerOptions { workers: 64, fail_fast: false },
            Arc::new(ok_outcome),
            None,
        );
        assert_eq!(report.outcomes.len(), 2);
    }

    // ---- property: exactly-once under random worker counts ---------------

    #[test]
    fn prop_exactly_once_any_worker_count() {
        use crate::testing::prop::check;
        check("scheduler-exactly-once", 25, |g| {
            let n = g.size(1, 40);
            let workers = g.size(1, 8);
            let counts: Arc<Vec<AtomicUsize>> =
                Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
            let c = Arc::clone(&counts);
            let report = run_all(
                specs(n),
                &SchedulerOptions { workers, fail_fast: false },
                Arc::new(move |s| {
                    c[s.index].fetch_add(1, Ordering::SeqCst);
                    ok_outcome(s)
                }),
                None,
            );
            crate::prop_assert!(report.outcomes.len() == n, "outcome count");
            for (i, c) in counts.iter().enumerate() {
                crate::prop_assert!(
                    c.load(Ordering::SeqCst) == 1,
                    "task {i} ran {} times",
                    c.load(Ordering::SeqCst)
                );
            }
            Ok(())
        });
    }
}
