//! Retry policies for flaky experiment tasks.
//!
//! The paper's fault-tolerance story is coarse-grained (rerun failed tasks
//! on the next invocation); production experiment runners also want
//! *in-run* retries for transient failures (OOM races, network datasets,
//! CUDA hiccups). [`RetryPolicy`] covers both: `none()` reproduces the
//! paper's behaviour, `fixed`/`exponential` add bounded in-run retries.
//!
//! One policy governs every way an attempt can end short of success:
//! `Err` returns and contained panics (all backends), worker crashes
//! (process/remote backends — the supervisor requeues the in-flight
//! attempt when a worker dies), and per-task wall-clock **timeouts**
//! (`--task-timeout`: a stuck attempt is stopped and requeued through
//! this same policy, so `max_attempts` bounds runaway configurations
//! exactly like flaky ones). The attempt counter is per *task*, shared
//! across those causes — a task that crashes once and times out once has
//! made two attempts.

use std::time::Duration;

/// Backoff shape between attempts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backoff {
    /// Same delay between all attempts.
    Fixed(Duration),
    /// `base * factor^(attempt-1)`, capped at `max`.
    Exponential {
        /// Delay before the first retry.
        base: Duration,
        /// Multiplier applied per further retry (≥ 1).
        factor: f64,
        /// Upper bound on any single delay.
        max: Duration,
    },
}

/// A bounded retry policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retries).
    pub max_attempts: u32,
    /// Delay shape between attempts.
    pub backoff: Backoff,
}

impl RetryPolicy {
    /// No retries: a single attempt (the paper's default behaviour).
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, backoff: Backoff::Fixed(Duration::ZERO) }
    }

    /// `attempts` total attempts with a fixed `delay` between them.
    pub fn fixed(attempts: u32, delay: Duration) -> RetryPolicy {
        RetryPolicy { max_attempts: attempts.max(1), backoff: Backoff::Fixed(delay) }
    }

    /// Exponential backoff: `base, base*factor, base*factor², …` capped at `max`.
    pub fn exponential(attempts: u32, base: Duration, factor: f64, max: Duration) -> RetryPolicy {
        RetryPolicy {
            max_attempts: attempts.max(1),
            backoff: Backoff::Exponential { base, factor: factor.max(1.0), max },
        }
    }

    /// Delay to sleep before attempt `next_attempt` (2-based: the delay
    /// after the first failure precedes attempt 2).
    pub fn delay_before(&self, next_attempt: u32) -> Duration {
        if next_attempt <= 1 {
            return Duration::ZERO;
        }
        match self.backoff {
            Backoff::Fixed(d) => d,
            Backoff::Exponential { base, factor, max } => {
                let exp = (next_attempt - 2) as i32;
                let secs = base.as_secs_f64() * factor.powi(exp);
                Duration::from_secs_f64(secs.min(max.as_secs_f64()))
            }
        }
    }

    /// Whether another attempt is allowed after `attempts_made` attempts.
    pub fn should_retry(&self, attempts_made: u32) -> bool {
        attempts_made < self.max_attempts
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_single_attempt() {
        let p = RetryPolicy::none();
        assert_eq!(p.max_attempts, 1);
        assert!(!p.should_retry(1));
        assert_eq!(p.delay_before(2), Duration::ZERO);
    }

    #[test]
    fn fixed_delays() {
        let p = RetryPolicy::fixed(3, Duration::from_millis(10));
        assert!(p.should_retry(1));
        assert!(p.should_retry(2));
        assert!(!p.should_retry(3));
        assert_eq!(p.delay_before(1), Duration::ZERO);
        assert_eq!(p.delay_before(2), Duration::from_millis(10));
        assert_eq!(p.delay_before(3), Duration::from_millis(10));
    }

    #[test]
    fn exponential_grows_and_caps() {
        let p = RetryPolicy::exponential(
            5,
            Duration::from_millis(100),
            2.0,
            Duration::from_millis(350),
        );
        assert_eq!(p.delay_before(2), Duration::from_millis(100));
        assert_eq!(p.delay_before(3), Duration::from_millis(200));
        assert_eq!(p.delay_before(4), Duration::from_millis(350)); // capped from 400
        assert_eq!(p.delay_before(5), Duration::from_millis(350));
    }

    #[test]
    fn zero_attempts_clamps_to_one() {
        assert_eq!(RetryPolicy::fixed(0, Duration::ZERO).max_attempts, 1);
        assert_eq!(
            RetryPolicy::exponential(0, Duration::ZERO, 0.5, Duration::ZERO).max_attempts,
            1
        );
    }
}
