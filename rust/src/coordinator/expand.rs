//! Matrix expansion: cartesian product minus exclusion rules.
//!
//! "MEMENTO automatically constructs tasks using every combination of
//! defined parameters" (§3). Expansion is *lazy* — an iterator in odometer
//! order over the declaration-ordered domains — so a 10^6-combination matrix
//! costs nothing until consumed, and exclusion filtering happens during
//! iteration.

use crate::config::matrix::{ConfigMatrix, ExcludeRule};
use crate::config::value::ParamValue;
use crate::coordinator::task::TaskSpec;
use std::borrow::Borrow;

/// Lazy iterator over the included combinations of a matrix.
///
/// Generic over how the matrix is held: `Expansion::new(&matrix)` borrows
/// (the common in-scope case), while `Expansion::new(matrix)` /
/// `Expansion::new(arc)` own it — which is what lets the streaming run
/// pipeline hand a `'static` expansion to worker threads without ever
/// materializing the product.
///
/// Exclusion rules are applied **against the odometer counters** (no spec
/// is allocated for an excluded combination), and a matching rule skips
/// its whole remaining *block* in one step: every combination agreeing
/// with the counters up to the rule's last constrained parameter is
/// excluded too, so the odometer jumps straight past them. A rule pinning
/// an early (slow-varying) parameter therefore skips its ~`raw/len`
/// combinations in O(1) instead of iterating them — without this, a long
/// excluded run would stall the first scheduler pull for hours while
/// holding the source lock.
pub struct Expansion<M: Borrow<ConfigMatrix> = ConfigMatrix> {
    matrix: M,
    /// Odometer over domain indices; `None` once exhausted.
    counters: Option<Vec<usize>>,
    /// Exclusion rules resolved to `(last constrained position, pairs of
    /// (position, value))`. Rules naming unknown parameters can never
    /// match a full assignment and are dropped (same semantics as
    /// [`is_excluded`]).
    rules: Vec<(usize, Vec<(usize, ParamValue)>)>,
    /// Running index over *included* tasks (the `TaskSpec::index`).
    next_index: usize,
    /// Raw combinations visited so far (included + excluded, where
    /// block-skipped exclusions count as visited).
    raw_visited: usize,
}

impl<M: Borrow<ConfigMatrix>> Expansion<M> {
    /// A lazy expansion over the matrix (owned or borrowed).
    pub fn new(matrix: M) -> Self {
        let m = matrix.borrow();
        let counters = if m.parameters.iter().any(|(_, d)| d.is_empty())
            || m.parameters.is_empty()
        {
            None
        } else {
            Some(vec![0; m.parameters.len()])
        };
        let rules = m
            .exclude
            .iter()
            .filter_map(|rule| {
                let mut pairs = Vec::with_capacity(rule.len());
                let mut max_pos = 0usize;
                for (key, want) in rule {
                    let pos = m.parameters.iter().position(|(n, _)| n == key)?;
                    max_pos = max_pos.max(pos);
                    pairs.push((pos, want.clone()));
                }
                Some((max_pos, pairs))
            })
            .collect();
        Expansion { matrix, counters, rules, next_index: 0, raw_visited: 0 }
    }

    /// Number of raw combinations visited so far (for progress reporting).
    pub fn raw_visited(&self) -> usize {
        self.raw_visited
    }

    fn current_spec(&self) -> TaskSpec {
        let counters = self.counters.as_ref().unwrap();
        let params = self
            .matrix
            .borrow()
            .parameters
            .iter()
            .zip(counters)
            .map(|((name, domain), &i)| (name.clone(), domain[i].clone()))
            .collect();
        TaskSpec { params, index: self.next_index, exp: None }
    }

    /// If the current counters match a rule, the last position that rule
    /// constrains (the whole block sharing `counters[..=pos]` is excluded).
    fn matched_rule_max_pos(&self) -> Option<usize> {
        let counters = self.counters.as_ref()?;
        let matrix = self.matrix.borrow();
        self.rules.iter().find_map(|(max_pos, pairs)| {
            pairs
                .iter()
                .all(|(pos, want)| &matrix.parameters[*pos].1[counters[*pos]] == want)
                .then_some(*max_pos)
        })
    }

    /// Raw combinations from the current position through the end of the
    /// block that fixes `counters[..=m]` (inclusive of the current one).
    fn remaining_in_block(&self, m: usize) -> usize {
        let matrix = self.matrix.borrow();
        let counters = self.counters.as_ref().unwrap();
        let mut rem = 1usize;
        let mut stride = 1usize;
        for pos in (m + 1..counters.len()).rev() {
            let len = matrix.parameters[pos].1.len();
            rem += (len - 1 - counters[pos]) * stride;
            stride *= len;
        }
        rem
    }

    /// Odometer increment at position `m`: positions after `m` reset to 0,
    /// carry propagates toward position 0. Last parameter fastest (matches
    /// nested-loop order of the paper's dict) when `m` is the last
    /// position; block skips pass the matched rule's last position.
    fn advance_at(&mut self, m: usize) {
        let matrix = self.matrix.borrow();
        let counters = match &mut self.counters {
            Some(c) => c,
            None => return,
        };
        for c in counters.iter_mut().skip(m + 1) {
            *c = 0;
        }
        for pos in (0..=m).rev() {
            counters[pos] += 1;
            if counters[pos] < matrix.parameters[pos].1.len() {
                return;
            }
            counters[pos] = 0;
        }
        self.counters = None;
    }

    fn advance(&mut self) {
        if let Some(c) = &self.counters {
            let last = c.len() - 1;
            self.advance_at(last);
        }
    }
}

impl<M: Borrow<ConfigMatrix>> Iterator for Expansion<M> {
    type Item = TaskSpec;

    fn next(&mut self) -> Option<TaskSpec> {
        loop {
            self.counters.as_ref()?;
            if let Some(m) = self.matched_rule_max_pos() {
                // Everything sharing counters[..=m] is excluded: account
                // for the block's remainder and leap straight past it.
                self.raw_visited += self.remaining_in_block(m);
                self.advance_at(m);
                continue;
            }
            let spec = self.current_spec();
            self.advance();
            self.raw_visited += 1;
            self.next_index += 1;
            return Some(spec);
        }
    }
}

/// True when the assignment matches *all* pairs of at least one rule.
pub fn is_excluded(spec: &TaskSpec, rules: &[ExcludeRule]) -> bool {
    rules.iter().any(|rule| rule_matches(spec, rule))
}

fn rule_matches(spec: &TaskSpec, rule: &ExcludeRule) -> bool {
    rule.iter().all(|(key, want)| {
        spec.get(key).map(|have| have == want).unwrap_or(false)
    })
}

/// Eagerly expands a matrix into the full included task list.
///
/// **Materializes every included task.** The run pipeline never calls
/// this — `Memento::launch` feeds the scheduler straight from a lazy
/// [`Expansion`] — so it survives as the oracle for expansion tests and as
/// a convenience for small, bounded matrices (sweep sampling, reports).
pub fn expand(matrix: &ConfigMatrix) -> Vec<TaskSpec> {
    Expansion::new(matrix).collect()
}

/// Counts included tasks without materializing them.
pub fn count_included(matrix: &ConfigMatrix) -> usize {
    Expansion::new(matrix).count()
}

/// Uniform reservoir sample (Algorithm R) of `k` specs from a lazy stream,
/// plus the total number of specs seen.
///
/// One pass, O(k) memory, every element kept with probability exactly
/// `k / seen` — which is what makes `memento expand --sample` an
/// *unbiased* preview of a huge matrix, where `--limit` only ever shows
/// the matrix's first block. Deterministic for a given seeded
/// [`Rng`](crate::util::rng::Rng). The sample is returned sorted by
/// expansion index for stable display; sampling itself is order-uniform.
pub fn reservoir_sample(
    it: impl Iterator<Item = TaskSpec>,
    k: usize,
    rng: &mut crate::util::rng::Rng,
) -> (Vec<TaskSpec>, usize) {
    let mut sample: Vec<TaskSpec> = Vec::with_capacity(k.min(1024));
    let mut seen = 0usize;
    for spec in it {
        seen += 1;
        if sample.len() < k {
            sample.push(spec);
        } else {
            // Keep the t-th element with probability k/t by overwriting a
            // uniformly random reservoir slot iff the drawn index < k.
            let j = rng.below(seen);
            if j < k {
                sample[j] = spec;
            }
        }
    }
    sample.sort_by_key(|s| s.index);
    (sample, seen)
}

/// Counts combinations removed by exclusion rules.
pub fn count_excluded(matrix: &ConfigMatrix) -> usize {
    matrix.raw_count() - count_included(matrix)
}

/// Helper for exclusion math in reports: how many raw combinations a single
/// rule matches (product of unconstrained domain sizes).
pub fn rule_match_count(matrix: &ConfigMatrix, rule: &ExcludeRule) -> usize {
    matrix
        .parameters
        .iter()
        .map(|(name, domain)| if rule.contains_key(name) { 1 } else { domain.len() })
        .product()
}

/// Groups the expansion by the values of one parameter, preserving order —
/// used by the report renderer to pivot result tables.
pub fn group_by_param<'m>(
    tasks: &'m [TaskSpec],
    param: &str,
) -> Vec<(ParamValue, Vec<&'m TaskSpec>)> {
    let mut groups: Vec<(ParamValue, Vec<&TaskSpec>)> = Vec::new();
    for t in tasks {
        let Some(v) = t.get(param) else { continue };
        match groups.iter_mut().find(|(gv, _)| gv == v) {
            Some((_, members)) => members.push(t),
            None => groups.push((v.clone(), vec![t])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::matrix::ConfigMatrix;
    use crate::config::value::{pv_int, pv_str};

    fn paper_matrix() -> ConfigMatrix {
        ConfigMatrix::builder()
            .param(
                "dataset",
                vec![pv_str("digits"), pv_str("wine"), pv_str("breast_cancer")],
            )
            .param(
                "feature_engineering",
                vec![pv_str("DummyImputer"), pv_str("SimpleImputer")],
            )
            .param(
                "preprocessing",
                vec![
                    pv_str("DummyPreprocessor"),
                    pv_str("MinMaxScaler"),
                    pv_str("StandardScaler"),
                ],
            )
            .param(
                "model",
                vec![pv_str("AdaBoost"), pv_str("RandomForest"), pv_str("SVC")],
            )
            .exclude(vec![
                ("dataset", pv_str("digits")),
                ("feature_engineering", pv_str("SimpleImputer")),
            ])
            .build()
            .unwrap()
    }

    #[test]
    fn paper_counts_54_raw_45_included() {
        // E1: the §3 worked example. 3×2×3×3 = 54 raw; the exclude rule
        // pins dataset and feature_engineering, leaving 3×3 = 9 excluded.
        let m = paper_matrix();
        assert_eq!(m.raw_count(), 54);
        assert_eq!(count_excluded(&m), 9);
        let tasks = expand(&m);
        assert_eq!(tasks.len(), 45);
        assert_eq!(rule_match_count(&m, &m.exclude[0]), 9);
    }

    #[test]
    fn no_excluded_combination_survives() {
        let tasks = expand(&paper_matrix());
        assert!(!tasks.iter().any(|t| {
            t.get("dataset") == Some(&pv_str("digits"))
                && t.get("feature_engineering") == Some(&pv_str("SimpleImputer"))
        }));
    }

    #[test]
    fn indices_are_contiguous_and_ordered() {
        let tasks = expand(&paper_matrix());
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.index, i);
        }
    }

    #[test]
    fn expansion_is_deterministic() {
        let m = paper_matrix();
        let a = expand(&m);
        let b = expand(&m);
        assert_eq!(a, b);
    }

    #[test]
    fn odometer_order_last_param_fastest() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1)])
            .param("b", vec![pv_int(0), pv_int(1)])
            .build()
            .unwrap();
        let order: Vec<(i64, i64)> = expand(&m)
            .iter()
            .map(|t| {
                (
                    t.get("a").unwrap().as_i64().unwrap(),
                    t.get("b").unwrap().as_i64().unwrap(),
                )
            })
            .collect();
        assert_eq!(order, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn single_param_matrix() {
        let m = ConfigMatrix::builder()
            .param("x", vec![pv_int(1), pv_int(2), pv_int(3)])
            .build()
            .unwrap();
        assert_eq!(expand(&m).len(), 3);
    }

    #[test]
    fn multiple_overlapping_excludes() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0), pv_int(1)])
            .param("b", vec![pv_int(0), pv_int(1)])
            .exclude(vec![("a", pv_int(0))])
            .exclude(vec![("b", pv_int(0))])
            .build()
            .unwrap();
        // a=0 removes 2, b=0 removes 2, overlap (0,0) counted once → 1 left.
        let tasks = expand(&m);
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].get("a"), Some(&pv_int(1)));
        assert_eq!(tasks[0].get("b"), Some(&pv_int(1)));
    }

    #[test]
    fn exclude_everything_yields_empty() {
        let m = ConfigMatrix::builder()
            .param("a", vec![pv_int(0)])
            .exclude(vec![("a", pv_int(0))])
            .build()
            .unwrap();
        assert_eq!(expand(&m).len(), 0);
        assert_eq!(count_excluded(&m), 1);
    }

    #[test]
    fn lazy_iteration_tracks_raw_visited() {
        let m = paper_matrix();
        let mut it = Expansion::new(&m);
        let _ = it.next().unwrap();
        assert!(it.raw_visited() >= 1);
        let rest: Vec<_> = it.collect();
        assert_eq!(rest.len(), 44);
    }

    #[test]
    fn group_by_param_partitions() {
        let m = paper_matrix();
        let tasks = expand(&m);
        let groups = group_by_param(&tasks, "dataset");
        assert_eq!(groups.len(), 3);
        let total: usize = groups.iter().map(|(_, g)| g.len()).sum();
        assert_eq!(total, 45);
        // digits lost its SimpleImputer combos: 1×3×3=9 vs 2×3×3=18.
        let digits = groups.iter().find(|(v, _)| v == &pv_str("digits")).unwrap();
        assert_eq!(digits.1.len(), 9);
        let wine = groups.iter().find(|(v, _)| v == &pv_str("wine")).unwrap();
        assert_eq!(wine.1.len(), 18);
    }

    // ---- property tests --------------------------------------------------

    use crate::testing::prop::{check, Gen};

    fn random_matrix(g: &mut Gen) -> ConfigMatrix {
        let n_params = g.size(1, 4);
        let mut b = ConfigMatrix::builder();
        let mut names = Vec::new();
        for i in 0..n_params {
            let name = format!("p{i}");
            let domain_len = g.size(1, 4);
            let domain: Vec<_> = (0..domain_len).map(|j| pv_int(j as i64)).collect();
            names.push((name.clone(), domain_len));
            b = b.param(name, domain);
        }
        // Random exclude rules drawn from actual domains.
        let n_rules = g.size(0, 3);
        for _ in 0..n_rules {
            let n_keys = g.size(1, names.len());
            let mut idx: Vec<usize> = (0..names.len()).collect();
            g.rng().shuffle(&mut idx);
            let pairs: Vec<(String, ParamValue)> = idx[..n_keys]
                .iter()
                .map(|&i| {
                    let (name, dlen) = &names[i];
                    (name.clone(), pv_int(g.size(0, dlen - 1) as i64))
                })
                .collect();
            b = b.exclude(pairs);
        }
        b.build().expect("generated matrix must validate")
    }

    #[test]
    fn prop_included_plus_excluded_equals_raw() {
        check("included+excluded=raw", 50, |g| {
            let m = random_matrix(g);
            let included = count_included(&m);
            let excluded = count_excluded(&m);
            crate::prop_assert!(
                included + excluded == m.raw_count(),
                "inc {included} + exc {excluded} != raw {}",
                m.raw_count()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_counts_match_bruteforce_enumeration() {
        // Independent oracle: decode every raw combination index with plain
        // div/mod arithmetic (no Expansion iterator involved) and apply the
        // exclusion predicate directly. Catches odometer bugs that a
        // self-referential count identity would miss.
        check("counts-match-bruteforce", 40, |g| {
            let m = random_matrix(g);
            let dims: Vec<usize> = m.parameters.iter().map(|(_, d)| d.len()).collect();
            let raw = m.raw_count();
            let mut included = 0usize;
            for mut k in 0..raw {
                let mut assignment: Vec<(String, ParamValue)> =
                    Vec::with_capacity(dims.len());
                // Last parameter fastest, matching the documented order.
                for (pi, &dlen) in dims.iter().enumerate().rev() {
                    let (name, domain) = &m.parameters[pi];
                    assignment.push((name.clone(), domain[k % dlen].clone()));
                    k /= dlen;
                }
                assignment.reverse();
                let spec = TaskSpec { params: assignment, index: 0, exp: None };
                if !is_excluded(&spec, &m.exclude) {
                    included += 1;
                }
            }
            crate::prop_assert!(
                included == count_included(&m),
                "bruteforce {included} != count_included {}",
                count_included(&m)
            );
            crate::prop_assert!(
                raw - included == count_excluded(&m),
                "bruteforce excluded {} != count_excluded {}",
                raw - included,
                count_excluded(&m)
            );
            Ok(())
        });
    }

    #[test]
    fn prop_no_survivor_matches_any_rule() {
        check("no-survivor-matches-rule", 50, |g| {
            let m = random_matrix(g);
            for t in expand(&m) {
                crate::prop_assert!(
                    !is_excluded(&t, &m.exclude),
                    "task {} survived exclusion",
                    t.label()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_task_ids_unique() {
        check("task-ids-unique", 30, |g| {
            let m = random_matrix(g);
            let tasks = expand(&m);
            let mut ids: Vec<_> = tasks.iter().map(|t| t.id("v1")).collect();
            let n = ids.len();
            ids.sort();
            ids.dedup();
            crate::prop_assert!(ids.len() == n, "duplicate task ids in expansion");
            Ok(())
        });
    }

    /// Independent *eager* oracle: decodes every raw combination by plain
    /// div/mod arithmetic and filters with the exclusion predicate — no
    /// `Expansion` involved. This is what "the old eager expand()" did,
    /// kept alive here purely as a reference implementation.
    fn eager_oracle(m: &ConfigMatrix) -> (Vec<TaskSpec>, usize) {
        let dims: Vec<usize> = m.parameters.iter().map(|(_, d)| d.len()).collect();
        let mut included = Vec::new();
        let mut excluded = 0usize;
        for mut k in 0..m.raw_count() {
            let mut assignment: Vec<(String, ParamValue)> = Vec::with_capacity(dims.len());
            for (pi, &dlen) in dims.iter().enumerate().rev() {
                let (name, domain) = &m.parameters[pi];
                assignment.push((name.clone(), domain[k % dlen].clone()));
                k /= dlen;
            }
            assignment.reverse();
            let spec = TaskSpec { params: assignment, index: included.len(), exp: None };
            if is_excluded(&spec, &m.exclude) {
                excluded += 1;
            } else {
                included.push(spec);
            }
        }
        (included, excluded)
    }

    #[test]
    fn prop_lazy_expansion_matches_eager_oracle() {
        // The lazy iterator must yield exactly the same task-id set (and
        // order, and indices) as the eager oracle, with identical
        // exclusion counts.
        check("lazy-matches-eager-oracle", 40, |g| {
            let m = random_matrix(g);
            let (eager, eager_excluded) = eager_oracle(&m);
            let lazy: Vec<TaskSpec> = Expansion::new(&m).collect();
            crate::prop_assert!(
                lazy.len() == eager.len(),
                "lazy yielded {} tasks, eager oracle {}",
                lazy.len(),
                eager.len()
            );
            let eager_ids: Vec<_> = eager.iter().map(|t| t.id("v1")).collect();
            let lazy_ids: Vec<_> = lazy.iter().map(|t| t.id("v1")).collect();
            crate::prop_assert!(
                lazy_ids == eager_ids,
                "task-id streams diverge between lazy and eager expansion"
            );
            for (i, t) in lazy.iter().enumerate() {
                crate::prop_assert!(t.index == i, "lazy index {i} -> {}", t.index);
            }
            crate::prop_assert!(
                count_excluded(&m) == eager_excluded,
                "exclusion counts diverge: lazy {} vs eager {}",
                count_excluded(&m),
                eager_excluded
            );
            Ok(())
        });
    }

    #[test]
    fn huge_matrix_first_k_specs_return_instantly() {
        // ~10^12 raw combinations (10^8)^... : 8 params × 32 values =
        // 32^8 ≈ 1.1e12. Taking the first k specs must cost O(k), not
        // O(raw): the product is never materialized (the old eager
        // expand() would OOM long before returning).
        let mut b = ConfigMatrix::builder();
        for p in 0..8 {
            b = b.param(format!("p{p}"), (0..32).map(|v| pv_int(v as i64)).collect());
        }
        // An exclusion rule so the lazy filter path is exercised too.
        let m = b.exclude(vec![("p7", pv_int(0))]).build().unwrap();
        assert!(m.raw_count() > 1_000_000_000_000usize, "raw={}", m.raw_count());

        let started = std::time::Instant::now();
        let mut it = Expansion::new(&m);
        let k = 10_000;
        let first_k: Vec<TaskSpec> = it.by_ref().take(k).collect();
        assert_eq!(first_k.len(), k);
        for (i, t) in first_k.iter().enumerate() {
            assert_eq!(t.index, i);
            assert_ne!(t.get("p7"), Some(&pv_int(0)), "excluded combo leaked");
        }
        // Visited raw combos stay proportional to k (k included plus the
        // interleaved exclusions), nowhere near the full product.
        assert!(
            it.raw_visited() < 2 * k + 64,
            "raw_visited {} suggests eager behavior",
            it.raw_visited()
        );
        // Generous bound: laziness makes this micro/milliseconds; eager
        // materialization would run for hours. Guards against regressions
        // that quietly re-materialize the product.
        assert!(
            started.elapsed() < std::time::Duration::from_secs(10),
            "first-k taking {:?} — expansion is no longer lazy",
            started.elapsed()
        );
    }

    // ---- reservoir sampling ----------------------------------------------

    #[test]
    fn reservoir_keeps_everything_when_k_covers_stream() {
        let m = paper_matrix();
        let mut rng = crate::util::rng::Rng::new(7);
        let (sample, seen) = reservoir_sample(Expansion::new(&m), 100, &mut rng);
        assert_eq!(seen, 45);
        assert_eq!(sample.len(), 45);
        for (i, t) in sample.iter().enumerate() {
            assert_eq!(t.index, i, "k >= n keeps the full ordered stream");
        }
    }

    #[test]
    fn reservoir_is_deterministic_per_seed() {
        let m = paper_matrix();
        let draw = |seed: u64| {
            let mut rng = crate::util::rng::Rng::new(seed);
            reservoir_sample(Expansion::new(&m), 10, &mut rng)
                .0
                .iter()
                .map(|t| t.index)
                .collect::<Vec<_>>()
        };
        assert_eq!(draw(42), draw(42), "same seed, same sample");
        assert_ne!(draw(42), draw(43), "different seed, different sample");
    }

    #[test]
    fn reservoir_sample_is_unbiased_across_blocks() {
        // `--limit` previews are biased to the matrix's first block; the
        // reservoir must not be. Sample 10 of 1000 across many seeds and
        // check both halves of the stream are drawn from equally (a
        // first-block-biased sampler would put everything in the first
        // half), and that per-element inclusion is ~uniform.
        let n = 1000usize;
        let k = 10usize;
        let trials = 400usize;
        let mut first_half = 0usize;
        let mut hits = vec![0usize; n];
        for seed in 0..trials as u64 {
            let mut rng = crate::util::rng::Rng::new(seed);
            let it = (0..n).map(|i| TaskSpec { params: Vec::new(), index: i, exp: None });
            let (sample, seen) = reservoir_sample(it, k, &mut rng);
            assert_eq!(seen, n);
            assert_eq!(sample.len(), k);
            let mut idx: Vec<usize> = sample.iter().map(|t| t.index).collect();
            idx.dedup();
            assert_eq!(idx.len(), k, "sample must hold distinct elements");
            for i in idx {
                hits[i] += 1;
                if i < n / 2 {
                    first_half += 1;
                }
            }
        }
        let total = trials * k;
        let frac = first_half as f64 / total as f64;
        assert!(
            (frac - 0.5).abs() < 0.05,
            "first-half fraction {frac} — sampler is block-biased"
        );
        // Expected hits per element: trials*k/n = 4. Loose 6σ-ish bound.
        let expect = total as f64 / n as f64;
        let max = *hits.iter().max().unwrap() as f64;
        assert!(max < expect * 5.0, "element drawn {max} times vs expected {expect}");
    }

    #[test]
    fn prop_without_rules_expansion_is_full_product() {
        check("no-rules-full-product", 30, |g| {
            let mut m = random_matrix(g);
            m.exclude.clear();
            crate::prop_assert!(
                count_included(&m) == m.raw_count(),
                "full product mismatch"
            );
            Ok(())
        });
    }
}
