//! Progress reporting: periodic `done/total (ETA …)` lines.
//!
//! A background thread wakes at a fixed interval and prints progress when it
//! changed since the last tick; the ETA extrapolates from the *recent*
//! completion rate — the spacing of the last [`ETA_WINDOW`] executed
//! completions — falling back to the whole-run rate until enough samples
//! exist. Silent when the run finishes between ticks — the final summary
//! comes from the notifier instead.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How many recent executed-completion timestamps the ETA rate window
/// keeps. Small enough that one lock push per completion is noise, large
/// enough to smooth per-task variance.
pub const ETA_WINDOW: usize = 32;

/// Shared progress state updated by the scheduler.
///
/// Two construction modes:
/// - [`ProgressState::new`] — the total is known up front (eager callers,
///   tests); behavior is unchanged from the pre-streaming API.
/// - [`ProgressState::streaming`] — the total *grows* as the lazy
///   expansion discovers pending tasks ([`ProgressState::add_planned`])
///   and becomes final once [`ProgressState::finish_planning`] runs; until
///   then renders mark the total as still-counting (`12/45+`).
#[derive(Debug)]
pub struct ProgressState {
    /// Executed (non-restored) tasks completed so far.
    pub done: AtomicUsize,
    /// Specs abandoned by a fail-fast abort. Tracked separately from `done`
    /// so the bar still reaches a terminal state (`done + skipped == total`)
    /// without pretending skipped work completed.
    pub skipped: AtomicUsize,
    /// Tasks restored from cache/checkpoint. Tracked separately from
    /// `done` (which counts *executed* completions) so restores are
    /// visible in renders without polluting the execution rate the ETA
    /// extrapolates from — a resume whose first completions are all
    /// near-instant restores has no execution evidence yet and must show
    /// no ETA rather than a garbage one.
    restored: AtomicUsize,
    planned: AtomicUsize,
    /// False while a streaming expansion may still grow `planned`.
    planning_done: AtomicBool,
    /// Timestamps of the last [`ETA_WINDOW`] executed completions; the ETA
    /// rate comes from their spacing so a run that sped up (or slowed
    /// down) converges on the current pace instead of averaging over the
    /// whole history. Restores never enter the window.
    recent: Mutex<VecDeque<Instant>>,
    start: Instant,
}

impl ProgressState {
    /// Progress over a total known up front (the eager API).
    pub fn new(total: usize) -> Arc<Self> {
        Arc::new(ProgressState {
            done: AtomicUsize::new(0),
            skipped: AtomicUsize::new(0),
            restored: AtomicUsize::new(0),
            planned: AtomicUsize::new(total),
            planning_done: AtomicBool::new(true),
            recent: Mutex::new(VecDeque::with_capacity(ETA_WINDOW)),
            start: Instant::now(),
        })
    }

    /// A state whose total is discovered incrementally by the lazy
    /// expansion stream.
    pub fn streaming() -> Arc<Self> {
        Arc::new(ProgressState {
            done: AtomicUsize::new(0),
            skipped: AtomicUsize::new(0),
            restored: AtomicUsize::new(0),
            planned: AtomicUsize::new(0),
            planning_done: AtomicBool::new(false),
            recent: Mutex::new(VecDeque::with_capacity(ETA_WINDOW)),
            start: Instant::now(),
        })
    }

    /// Registers `n` newly discovered pending tasks.
    pub fn add_planned(&self, n: usize) {
        self.planned.fetch_add(n, Ordering::Relaxed);
    }

    /// Marks the expansion stream exhausted: the total is now final.
    pub fn finish_planning(&self) {
        self.planning_done.store(true, Ordering::Relaxed);
    }

    /// True once the total can no longer grow.
    pub fn planning_complete(&self) -> bool {
        self.planning_done.load(Ordering::Relaxed)
    }

    /// The (possibly still growing) total.
    pub fn total(&self) -> usize {
        self.planned.load(Ordering::Relaxed)
    }

    /// Records one executed task completion and its timestamp (the ETA
    /// rate window).
    pub fn mark_done(&self) {
        self.done.fetch_add(1, Ordering::Relaxed);
        let mut recent = self.recent.lock().unwrap_or_else(|e| e.into_inner());
        if recent.len() == ETA_WINDOW {
            recent.pop_front();
        }
        recent.push_back(Instant::now());
    }

    /// Records a spec the scheduler abandoned after a fail-fast abort.
    pub fn mark_skipped(&self) {
        self.skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Records a task restored from cache/checkpoint (never executed).
    /// Restores render separately and are excluded from the ETA's
    /// execution rate.
    pub fn mark_restored(&self) {
        self.restored.fetch_add(1, Ordering::Relaxed);
    }

    /// Tasks restored so far.
    pub fn restored_count(&self) -> usize {
        self.restored.load(Ordering::Relaxed)
    }

    /// `(done, total)` as of now.
    pub fn snapshot(&self) -> (usize, usize) {
        (self.done.load(Ordering::Relaxed), self.total())
    }

    /// `(done, skipped, total)`; on any terminal run state
    /// `done + skipped == total`.
    pub fn snapshot_full(&self) -> (usize, usize, usize) {
        (
            self.done.load(Ordering::Relaxed),
            self.skipped.load(Ordering::Relaxed),
            self.total(),
        )
    }

    /// The *windowed* executed-completion rate (tasks/second): the pace
    /// of the last [`ETA_WINDOW`] completions, `None` until two samples
    /// with measurable spacing exist. This is the observed rate the ETA
    /// extrapolates from and the one telemetry snapshots report.
    pub fn recent_rate(&self) -> Option<f64> {
        let recent = self.recent.lock().unwrap_or_else(|e| e.into_inner());
        match (recent.front(), recent.back()) {
            (Some(first), Some(last)) if recent.len() >= 2 => {
                let rate = (recent.len() - 1) as f64 / (*last - *first).as_secs_f64();
                (rate.is_finite() && rate > 0.0).then_some(rate)
            }
            _ => None,
        }
    }

    /// Estimated seconds remaining, `None` until at least one **executed**
    /// task has finished (or while the streaming total is still being
    /// discovered). Restored tasks are near-instant and carry no
    /// execution-rate evidence: a resume whose first completions are all
    /// cache/checkpoint restores must show no ETA instead of
    /// extrapolating `inf`/garbage from a zero observed rate — the rate
    /// is additionally guarded to be finite and positive before dividing.
    ///
    /// The rate is *windowed*: once two or more of the last [`ETA_WINDOW`]
    /// completions have measurable spacing, the estimate extrapolates
    /// from their pace, so a run whose tasks sped up (warm caches,
    /// workers joining) or slowed down converges on the current rate
    /// instead of averaging over the whole history. With only one
    /// completion — or a degenerate zero-width window — it falls back to
    /// the whole-run executed rate, preserving the "ETA appears after the
    /// first executed completion" behavior.
    pub fn eta_secs(&self) -> Option<f64> {
        let executed = self.done.load(Ordering::Relaxed);
        let total = self.total();
        if executed == 0 || total == 0 || !self.planning_complete() {
            return None;
        }
        let rate = self
            .recent_rate()
            .unwrap_or_else(|| executed as f64 / self.start.elapsed().as_secs_f64());
        if !rate.is_finite() || rate <= 0.0 {
            return None;
        }
        Some(((total.saturating_sub(executed)) as f64 / rate).max(0.0))
    }

    /// Renders a `[####....] 12/45 (ETA 3.2s)` line; skipped specs append
    /// a `(k skipped)` marker instead of inflating the done count,
    /// restored tasks append `(k restored)`, and a still-streaming total
    /// renders with a trailing `+`.
    pub fn render(&self) -> String {
        let (done, skipped, total) = self.snapshot_full();
        let restored = self.restored_count();
        let width = 24usize;
        let filled = if total == 0 { width } else { (width * done / total).min(width) };
        let bar: String = (0..width).map(|i| if i < filled { '#' } else { '.' }).collect();
        let eta = match self.eta_secs() {
            Some(s) if done + skipped < total => {
                format!(" (ETA {})", crate::util::time::fmt_secs(s))
            }
            _ => String::new(),
        };
        let plus = if self.planning_complete() { "" } else { "+" };
        let skip = if skipped > 0 { format!(" ({skipped} skipped)") } else { String::new() };
        let rest = if restored > 0 { format!(" ({restored} restored)") } else { String::new() };
        format!("[{bar}] {done}/{total}{plus}{rest}{skip}{eta}")
    }
}

/// Background printer; stops (and joins) on drop.
pub struct ProgressReporter {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ProgressReporter {
    /// Starts printing `state.render()` every `interval` while progress
    /// changes. Pass `quiet = true` to create a no-op reporter.
    pub fn start(state: Arc<ProgressState>, interval: Duration, quiet: bool) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        if quiet {
            return ProgressReporter { stop, handle: None };
        }
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("memento-progress".into())
            .spawn(move || {
                let mut last_done = usize::MAX;
                while !stop2.load(Ordering::Relaxed) {
                    std::thread::sleep(interval);
                    let (done, total) = state.snapshot();
                    if done != last_done && done < total {
                        println!("{}", state.render());
                        last_done = done;
                    }
                }
            })
            .expect("spawn progress reporter");
        ProgressReporter { stop, handle: Some(handle) }
    }
}

impl Drop for ProgressReporter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_mark() {
        let p = ProgressState::new(10);
        assert_eq!(p.snapshot(), (0, 10));
        p.mark_done();
        p.mark_done();
        assert_eq!(p.snapshot(), (2, 10));
    }

    #[test]
    fn recent_rate_needs_two_spaced_samples() {
        let p = ProgressState::new(4);
        assert!(p.recent_rate().is_none());
        p.mark_done();
        assert!(p.recent_rate().is_none(), "one sample has no spacing");
        std::thread::sleep(Duration::from_millis(2));
        p.mark_done();
        let rate = p.recent_rate().expect("two spaced completions");
        assert!(rate.is_finite() && rate > 0.0, "rate={rate}");
    }

    #[test]
    fn eta_appears_after_first_completion() {
        let p = ProgressState::new(4);
        assert!(p.eta_secs().is_none());
        p.mark_done();
        std::thread::sleep(Duration::from_millis(2));
        let eta = p.eta_secs().unwrap();
        assert!(eta >= 0.0);
    }

    #[test]
    fn render_shape() {
        let p = ProgressState::new(4);
        p.mark_done();
        let r = p.render();
        assert!(r.contains("1/4"), "{r}");
        assert!(r.starts_with('['), "{r}");
        // full bar at completion, no ETA suffix
        for _ in 0..3 {
            p.mark_done();
        }
        let r = p.render();
        assert!(r.contains("4/4"), "{r}");
        assert!(!r.contains("ETA"), "{r}");
    }

    #[test]
    fn skipped_reaches_terminal_state_without_eta() {
        let p = ProgressState::new(4);
        p.mark_done();
        p.mark_skipped();
        p.mark_skipped();
        p.mark_skipped();
        let (done, skipped, total) = p.snapshot_full();
        assert_eq!((done, skipped, total), (1, 3, 4));
        let r = p.render();
        assert!(r.contains("1/4"), "{r}");
        assert!(r.contains("(3 skipped)"), "{r}");
        assert!(!r.contains("ETA"), "terminal state must not show ETA: {r}");
    }

    #[test]
    fn streaming_total_grows_then_finalizes() {
        let p = ProgressState::streaming();
        assert!(!p.planning_complete());
        assert_eq!(p.total(), 0);
        p.add_planned(3);
        p.mark_done();
        let r = p.render();
        assert!(r.contains("1/3+"), "still-planning marker missing: {r}");
        assert!(p.eta_secs().is_none(), "no ETA while total can grow");
        p.add_planned(1);
        p.finish_planning();
        assert!(p.planning_complete());
        assert_eq!(p.snapshot(), (1, 4));
        let r = p.render();
        assert!(r.contains("1/4"), "{r}");
        assert!(!r.contains("4+"), "{r}");
        std::thread::sleep(Duration::from_millis(2));
        assert!(p.eta_secs().is_some());
    }

    #[test]
    fn eta_is_none_while_only_restores_have_completed() {
        // Regression: a resume whose first completions are all
        // cache/checkpoint restores has zero executed-task rate. The old
        // formula divided by the observed rate; the ETA must stay None
        // until at least one *executed* task has finished, however many
        // restores have landed.
        let p = ProgressState::streaming();
        p.add_planned(10);
        p.finish_planning();
        for _ in 0..1000 {
            p.mark_restored();
        }
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(p.restored_count(), 1000);
        assert!(
            p.eta_secs().is_none(),
            "restores alone must not produce an ETA"
        );
        let r = p.render();
        assert!(r.contains("(1000 restored)"), "{r}");
        assert!(!r.contains("ETA"), "{r}");
        assert!(!r.contains("inf"), "garbage ETA leaked into render: {r}");
        // One executed completion unlocks a finite ETA.
        p.mark_done();
        std::thread::sleep(Duration::from_millis(2));
        let eta = p.eta_secs().expect("executed completion yields an ETA");
        assert!(eta.is_finite() && eta >= 0.0, "eta={eta}");
    }

    #[test]
    fn eta_tracks_the_recent_rate_not_the_whole_run_average() {
        let p = ProgressState::new(10);
        // A long idle stretch before the first completion drags the
        // whole-run average down; the windowed rate must ignore it.
        std::thread::sleep(Duration::from_millis(200));
        p.mark_done();
        std::thread::sleep(Duration::from_millis(5));
        p.mark_done();
        let whole_run_eta = {
            let elapsed = Duration::from_millis(205).as_secs_f64();
            8.0 / (2.0 / elapsed) // ≈ 0.82 s if the old formula were used
        };
        let eta = p.eta_secs().expect("two completions yield an ETA");
        assert!(
            eta < whole_run_eta * 0.75,
            "eta {eta} should reflect the ~5ms recent spacing, not the \
             whole-run average (~{whole_run_eta})"
        );
    }

    #[test]
    fn eta_survives_many_more_completions_than_the_window() {
        let p = ProgressState::new(200);
        for _ in 0..ETA_WINDOW + 40 {
            p.mark_done();
        }
        std::thread::sleep(Duration::from_millis(2));
        p.mark_done();
        // The window is capped (old samples evicted) and a degenerate
        // zero-width window falls back to the whole-run rate rather than
        // returning None or a non-finite value.
        let eta = p.eta_secs().expect("ETA after window overflow");
        assert!(eta.is_finite() && eta >= 0.0, "eta={eta}");
    }

    #[test]
    fn zero_total_does_not_divide_by_zero() {
        let p = ProgressState::new(0);
        let r = p.render();
        assert!(r.contains("0/0"), "{r}");
        assert!(p.eta_secs().is_none());
    }

    #[test]
    fn reporter_stops_on_drop() {
        let p = ProgressState::new(2);
        {
            let _r = ProgressReporter::start(Arc::clone(&p), Duration::from_millis(5), true);
            p.mark_done();
        } // drop joins
        {
            let _r = ProgressReporter::start(Arc::clone(&p), Duration::from_millis(1), false);
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}
