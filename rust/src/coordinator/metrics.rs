//! Run metrics: counters, timers, and the end-of-run summary block.
//!
//! Thread-safe by construction (atomics + per-worker reservoir stripes
//! merged on read); every worker records into the same registry without
//! contending on a shared lock. The summary block is what the `memento`
//! CLI prints after a run and what the benches sample.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A lock-free monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Adds one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Adds `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Aggregated duration samples: lock-free sum/count plus a **striped**
/// reservoir for p50/p95.
///
/// The reservoir used to be a single `Mutex<Vec<u64>>`, which serialized
/// every worker on one lock — fine at one sample per dispatch chunk, but
/// a real bottleneck for per-task timers (`exec_time`) at 10⁵+ tasks/s.
/// Samples now land in per-worker stripes: each recording thread is
/// assigned a stripe once (thread-local), so workers write disjoint locks
/// with zero contention in the steady state, and readers merge the
/// stripes on demand (`percentile` is a cold path — it runs once per
/// run summary, not per task).
#[derive(Debug)]
pub struct Timer {
    sum_ns: AtomicU64,
    count: AtomicU64,
    stripes: Vec<Stripe>,
}

/// One per-worker reservoir stripe.
#[derive(Debug, Default)]
struct Stripe {
    /// Samples recorded through this stripe (drives slot replacement).
    n: AtomicU64,
    samples: Mutex<Vec<u64>>,
}

/// Per-stripe sample capacity — the same as the old single-mutex
/// reservoir, so a run recording from one thread retains exactly as many
/// samples as before; fully-striped runs retain up to 16× (512 KiB per
/// timer worst case, a non-issue for a per-run registry).
const RESERVOIR_CAP: usize = 4096;
const RESERVOIR_STRIPES: usize = 16;
const STRIPE_CAP: usize = RESERVOIR_CAP;

/// Stable per-thread stripe assignment: threads get consecutive indices
/// on first use, so up to `RESERVOIR_STRIPES` workers never share a lock.
fn stripe_index() -> usize {
    static NEXT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    thread_local! {
        static STRIPE: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    STRIPE.with(|s| *s % RESERVOIR_STRIPES)
}

impl Default for Timer {
    fn default() -> Self {
        Timer {
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            stripes: (0..RESERVOIR_STRIPES).map(|_| Stripe::default()).collect(),
        }
    }
}

impl Timer {
    /// Records one duration sample.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let stripe = &self.stripes[stripe_index()];
        let n = stripe.n.fetch_add(1, Ordering::Relaxed);
        let mut samples = stripe.samples.lock().unwrap();
        if samples.len() < STRIPE_CAP {
            samples.push(ns);
        } else {
            // Cheap deterministic reservoir variant: rotate through slots.
            samples[(n as usize) % STRIPE_CAP] = ns;
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples.
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed))
    }

    /// Mean sample (zero with no samples).
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
        }
    }

    /// Merges every stripe's samples (read-side cost, paid once per
    /// summary render — the write path never sees it).
    pub fn percentile(&self, p: f64) -> Duration {
        let mut samples: Vec<u64> = Vec::new();
        for stripe in &self.stripes {
            samples.extend(stripe.samples.lock().unwrap().iter().copied());
        }
        if samples.is_empty() {
            return Duration::ZERO;
        }
        samples.sort_unstable();
        let idx = ((samples.len() as f64 - 1.0) * p).round() as usize;
        Duration::from_nanos(samples[idx.min(samples.len() - 1)])
    }

    /// Samples currently retained across all stripes (tests/diagnostics).
    fn reservoir_len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.samples.lock().unwrap().len())
            .sum()
    }
}

/// The per-run metrics registry.
#[derive(Debug, Default)]
pub struct RunMetrics {
    /// Tasks that reached a terminal outcome (executed, not restored).
    pub tasks_total: Counter,
    /// Tasks whose final outcome succeeded.
    pub tasks_succeeded: Counter,
    /// Tasks whose final outcome failed.
    pub tasks_failed: Counter,
    /// Tasks restored from cache or a resumed checkpoint.
    pub tasks_cached: Counter,
    /// Retry attempts dispatched beyond each task's first.
    pub tasks_retried: Counter,
    /// Attempts stopped for exceeding the per-task wall-clock budget
    /// (`--task-timeout`; process/remote backends only).
    pub tasks_timed_out: Counter,
    /// Specs abandoned by a fail-fast abort (never executed).
    pub tasks_skipped: Counter,
    /// Result-cache lookups that hit.
    pub cache_hits: Counter,
    /// Result-cache lookups that missed.
    pub cache_misses: Counter,
    /// Checkpoint manifest flushes performed.
    pub checkpoint_flushes: Counter,
    /// Chunk jobs the scheduler submitted to the pool (batched dispatch).
    pub dispatch_chunks: Counter,
    /// Chunks a pool worker took from a sibling's queue — direct evidence
    /// of load-balancing; high values mean uneven task durations, not a
    /// problem per se.
    pub steals: Counter,
    /// Time spent inside experiment functions.
    pub exec_time: Timer,
    /// Queue wait: chunk submission → first task start, sampled once per
    /// executed dispatch chunk (skipped chunks are excluded so fail-fast
    /// aborts cannot pollute the distribution). Reflects queue depth plus
    /// pool wake-up latency, not just dispatch cost.
    pub dispatch_overhead: Timer,
}

impl RunMetrics {
    /// A zeroed registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Tasks per second of cumulative execution time.
    pub fn throughput(&self, wall_secs: f64) -> f64 {
        if wall_secs <= 0.0 {
            return 0.0;
        }
        self.tasks_total.get() as f64 / wall_secs
    }

    /// Multi-line summary block.
    pub fn render(&self, wall_secs: f64) -> String {
        let mut s = String::new();
        s.push_str("run metrics:\n");
        s.push_str(&format!(
            "  tasks      total={} ok={} failed={} cached={} retried={} timed-out={} skipped={}\n",
            self.tasks_total.get(),
            self.tasks_succeeded.get(),
            self.tasks_failed.get(),
            self.tasks_cached.get(),
            self.tasks_retried.get(),
            self.tasks_timed_out.get(),
            self.tasks_skipped.get(),
        ));
        s.push_str(&format!(
            "  cache      hits={} misses={}\n",
            self.cache_hits.get(),
            self.cache_misses.get(),
        ));
        s.push_str(&format!(
            "  dispatch   chunks={} steals={}\n",
            self.dispatch_chunks.get(),
            self.steals.get(),
        ));
        s.push_str(&format!(
            "  checkpoint flushes={}\n",
            self.checkpoint_flushes.get()
        ));
        s.push_str(&format!(
            "  exec       total={} mean={} p95={}\n",
            crate::util::time::fmt_duration(self.exec_time.total()),
            crate::util::time::fmt_duration(self.exec_time.mean()),
            crate::util::time::fmt_duration(self.exec_time.percentile(0.95)),
        ));
        s.push_str(&format!(
            "  queue-wait mean={} p95={}\n",
            crate::util::time::fmt_duration(self.dispatch_overhead.mean()),
            crate::util::time::fmt_duration(self.dispatch_overhead.percentile(0.95)),
        ));
        s.push_str(&format!(
            "  wall       {} ({:.1} tasks/s)\n",
            crate::util::time::fmt_secs(wall_secs),
            self.throughput(wall_secs),
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_stats() {
        let t = Timer::default();
        for ms in [10u64, 20, 30] {
            t.record(Duration::from_millis(ms));
        }
        assert_eq!(t.count(), 3);
        assert_eq!(t.total(), Duration::from_millis(60));
        assert_eq!(t.mean(), Duration::from_millis(20));
        assert_eq!(t.percentile(0.5), Duration::from_millis(20));
        assert_eq!(t.percentile(1.0), Duration::from_millis(30));
        let empty = Timer::default();
        assert_eq!(empty.mean(), Duration::ZERO);
        assert_eq!(empty.percentile(0.5), Duration::ZERO);
    }

    #[test]
    fn timer_reservoir_bounded() {
        let t = Timer::default();
        for i in 0..(RESERVOIR_CAP + 100) {
            t.record(Duration::from_nanos(i as u64));
        }
        assert_eq!(t.count() as usize, RESERVOIR_CAP + 100);
        assert!(t.reservoir_len() <= RESERVOIR_CAP);
        // percentile still answers from the retained samples
        assert!(t.percentile(0.5) > Duration::ZERO);
    }

    #[test]
    fn timer_stripes_merge_across_threads() {
        // Samples recorded from many threads land in different stripes
        // but merge into one distribution on read.
        let t = std::sync::Arc::new(Timer::default());
        let mut handles = Vec::new();
        for w in 0..8u64 {
            let t = std::sync::Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    t.record(Duration::from_nanos((w + 1) * 1000));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.count(), 800);
        // Stripe assignment is global across the process, so concurrent
        // tests may make some of our threads share a stripe (bounded
        // replacement) — the retained count is bounded, not exact.
        assert!(t.reservoir_len() <= 800);
        assert!(t.reservoir_len() >= STRIPE_CAP.min(800));
        // The merged distribution spans multiple threads' values, proving
        // the read side sees more than one stripe.
        assert!(t.percentile(0.0) >= Duration::from_nanos(1000));
        assert!(t.percentile(1.0) <= Duration::from_nanos(8000));
        assert!(t.percentile(0.0) < t.percentile(1.0));
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(RunMetrics::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = std::sync::Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.tasks_total.inc();
                    m.exec_time.record(Duration::from_nanos(100));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.tasks_total.get(), 4000);
        assert_eq!(m.exec_time.count(), 4000);
    }

    #[test]
    fn render_contains_fields() {
        let m = RunMetrics::new();
        m.tasks_total.add(45);
        m.tasks_succeeded.add(44);
        m.tasks_failed.add(1);
        let r = m.render(2.0);
        assert!(r.contains("total=45"), "{r}");
        assert!(r.contains("ok=44"), "{r}");
        assert!(r.contains("22.5 tasks/s"), "{r}");
    }

    #[test]
    fn render_contains_dispatch_fields() {
        let m = RunMetrics::new();
        m.dispatch_chunks.add(12);
        m.steals.add(3);
        m.tasks_skipped.add(7);
        let r = m.render(1.0);
        assert!(r.contains("chunks=12"), "{r}");
        assert!(r.contains("steals=3"), "{r}");
        assert!(r.contains("skipped=7"), "{r}");
    }

    #[test]
    fn throughput_zero_wall() {
        let m = RunMetrics::new();
        assert_eq!(m.throughput(0.0), 0.0);
    }
}
