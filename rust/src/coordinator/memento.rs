//! The Memento façade — the paper's §3 API, in Rust.
//!
//! ```no_run
//! use memento::prelude::*;
//!
//! let matrix = ConfigMatrix::builder()
//!     .param("model", vec![pv_str("AdaBoost"), pv_str("SVC")])
//!     .setting("n_fold", Json::int(5))
//!     .build()?;
//!
//! let results = Memento::new(|ctx| {
//!     let model = ctx.param_str("model")?;
//!     // … run the experiment …
//!     Ok(Json::obj(vec![("accuracy", Json::Num(0.9))]))
//! })
//! .workers(8)
//! .with_cache_dir("cache/")
//! .with_checkpoint_dir("runs/demo")
//! .with_notifier(Box::new(ConsoleNotificationProvider))
//! .run(&matrix)?;
//! # Ok::<(), memento::prelude::MementoError>(())
//! ```
//!
//! The run pipeline, per task:
//!
//! 1. **cache** — if the task id has a cached value (same params + same
//!    experiment version), restore it without executing; warm entries are
//!    served from the [`ResultCache`] memory tier without touching disk;
//! 2. **checkpoint** — if a resumed manifest already has the task, restore;
//! 3. **execute** — call the experiment function with a [`TaskContext`]
//!    (typed params, settings, deterministic seed, progress slot), catching
//!    both `Err` returns and panics;
//! 4. **retry** — per [`RetryPolicy`];
//! 5. **record** — cache the value (write-through both tiers), checkpoint
//!    the outcome, notify on failure, update metrics and progress.
//!
//! Pending tasks are pulled lazily from the expansion stream by the
//! scheduler's workers (see [`crate::coordinator::scheduler::run_stream`]);
//! pull/steal/skip counters land in [`RunMetrics`] so `memento run`'s
//! summary shows how the run was balanced.
//!
//! Two entry points share that pipeline:
//! - [`Memento::run`]/[`Memento::resume`] — the paper's blocking API,
//!   returning a [`ResultSet`];
//! - [`Memento::launch`]/[`Memento::launch_resume`] — the streaming API,
//!   returning a live [`Run`] handle whose [`Run::events`] yields typed
//!   [`RunEvent`]s (`TaskStarted`, `TaskFinished`, `Progress`,
//!   `WorkerCrashed`, `RunComplete`) as they happen. `run()` is literally
//!   `launch()?.collect()`.

use crate::config::matrix::ConfigMatrix;
use crate::coordinator::cache::ResultCache;
use crate::coordinator::checkpoint::CheckpointStore;
use crate::coordinator::error::{panic_message, FailureKind, MementoError, TaskFailure};
use crate::coordinator::expand;
use crate::coordinator::inflight::{Claim, InflightGate};
use crate::coordinator::journal::{Event, Journal};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::notify::{Notification, NotificationProvider};
use crate::coordinator::progress::{ProgressReporter, ProgressState};
use crate::coordinator::results::{ResultSet, TaskOutcome, TaskStatus};
use crate::coordinator::retry::RetryPolicy;
use crate::coordinator::run::{ChannelPolicy, EventSink, GatedNotifier, Run, RunEvent, RunSummary};
use crate::coordinator::scheduler::{
    ExecBackend, SchedulerOptions, SpecFilter, SpecSource, StreamHooks,
};
use crate::coordinator::task::{fresh_run_id, task_seed, TaskContext, TaskId, TaskSpec};
use crate::experiments::registry::Registry;
use crate::obs::snapshot::{write_snapshot, FleetStats, MetricsSnapshot};
use crate::obs::trace::{thread_worker_id, SpanState, Tracer};
use crate::store::ResultStore;
use crate::util::codec::WireFormat;
use crate::util::json::Json;
use crate::util::time::Stopwatch;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The experiment function signature (the paper's `exp_func`).
pub type ExpFn = dyn Fn(&TaskContext) -> Result<Json, MementoError> + Send + Sync;

/// Tuning knobs for a run; all have sensible defaults.
#[derive(Clone)]
pub struct RunOptions {
    /// Worker threads (thread backend) — process/remote backends carry
    /// their own worker counts in [`ExecBackend`].
    pub workers: usize,
    /// Stop dispatching after the first failed task.
    pub fail_fast: bool,
    /// Salt for task hashes; bump when the experiment code changes.
    pub version: String,
    /// Base seed; per-task seeds derive from it and the task id.
    pub seed: u64,
    /// In-run retry policy for failed attempts (and, on the IPC backends,
    /// worker crashes and task timeouts).
    pub retry: RetryPolicy,
    /// Per-task wall-clock budget for the process/remote backends: an
    /// attempt still running after this long is stopped, journaled as a
    /// timeout, and requeued under `retry`. `None` = unbounded. (The
    /// thread backend cannot safely stop a running closure, so it
    /// ignores this.)
    pub task_timeout: Option<Duration>,
    /// Checkpoint manifest flush interval in completed tasks.
    pub checkpoint_flush_every: usize,
    /// Print progress lines at this interval (None = quiet).
    pub progress_interval: Option<Duration>,
    /// Execution tier: in-process threads (default) or isolated worker
    /// processes (see [`crate::ipc`]).
    pub backend: ExecBackend,
    /// Buffering policy for the [`Run`] event channel. The default is
    /// unbounded (launch() behavior unchanged); a bounded policy caps
    /// channel memory, coalescing intermediate progress events under
    /// pressure and backpressuring terminal ones.
    pub events: ChannelPolicy,
    /// Payload encoding for IPC frames (process/remote backends) and for
    /// documents this run writes at rest (cache entries, checkpoint
    /// manifest/progress). Binary by default; readers always auto-detect,
    /// and peers that only speak JSON get JSON regardless.
    pub wire: WireFormat,
    /// Span-trace output directory. When set, every task attempt's state
    /// timeline (`queued → dispatched|restored → exec_start → exec_end →
    /// recorded`) is recorded into `<dir>/trace.jsonl` in the run's
    /// [`RunOptions::wire`] format (see [`crate::obs::trace`]). `None`
    /// (the default) disables tracing entirely — no tracer is created
    /// and the record paths are a skipped `Option` check.
    pub trace_dir: Option<PathBuf>,
    /// Live-telemetry interval. When set, a sampler thread emits a
    /// [`crate::obs::snapshot::MetricsSnapshot`] as
    /// [`RunEvent::Telemetry`] at this cadence. Telemetry events are
    /// coalescable: under a bounded event channel they collapse rather
    /// than backpressure the run. `None` (the default) disables the
    /// sampler.
    pub telemetry_every: Option<Duration>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            workers: crate::util::pool::num_cpus(),
            fail_fast: false,
            version: "v1".to_string(),
            seed: 0,
            retry: RetryPolicy::none(),
            task_timeout: None,
            checkpoint_flush_every: 1,
            progress_interval: None,
            backend: ExecBackend::Threads,
            events: ChannelPolicy::Unbounded,
            wire: WireFormat::default(),
            trace_dir: None,
            telemetry_every: None,
        }
    }
}

/// The orchestrator. Construct with [`Memento::new`], configure with the
/// builder methods, execute with [`Memento::run`] or [`Memento::resume`].
pub struct Memento {
    /// The experiment registry tasks resolve against. [`Memento::new`]
    /// installs a one-fallback registry (the pre-registry single
    /// experiment); [`Memento::with_registry`] installs a named mapping.
    registry: Arc<Registry>,
    /// Run-level experiment selection: every row without its own `exp`
    /// parameter targets this named entry.
    exp: Option<String>,
    options: RunOptions,
    cache: Option<Arc<ResultCache>>,
    /// Cross-run result database ([`crate::store`]): when set (and no
    /// explicit cache was installed), results land as records in this
    /// shared store, and a configured checkpoint dir keeps its manifest +
    /// completions there too (keyed by the dir name as run label).
    store: Option<Arc<ResultStore>>,
    checkpoint_dir: Option<PathBuf>,
    notifier: Option<Arc<dyn NotificationProvider>>,
    metrics: Arc<RunMetrics>,
    journal: Option<Arc<Journal>>,
    /// Argv for spawned worker processes (process backend). `None` = the
    /// current process's own arguments.
    worker_args: Option<Vec<String>>,
    /// Shared token remote workers must present (remote backend).
    auth_token: Option<String>,
    /// Standing worker pool to lease from (remote backend); when set, the
    /// run reuses it instead of binding a fresh listener.
    #[cfg(unix)]
    pool: Option<Arc<crate::ipc::pool::WorkerPool>>,
    /// Cross-run execute-once gate (see [`InflightGate`]); installed by
    /// coordinators running many concurrent runs over one shared store.
    inflight: Option<Arc<InflightGate>>,
    /// Explicit run label for the cross-run store, overriding the
    /// checkpoint-dir-name default (the daemon labels runs
    /// `tenant/run_id`).
    run_label: Option<String>,
}

impl Memento {
    /// Wraps an experiment function.
    ///
    /// Equivalent to [`Memento::with_registry`] over [`Registry::solo`]:
    /// the function becomes the registry's unnamed fallback, every task
    /// stays unnamed, and task ids are byte-identical to pre-registry
    /// versions — existing caches, checkpoints, and stores keep restoring.
    pub fn new(
        exp_fn: impl Fn(&TaskContext) -> Result<Json, MementoError> + Send + Sync + 'static,
    ) -> Memento {
        Memento::with_registry(Registry::solo(Arc::new(exp_fn)))
    }

    /// Wraps a named experiment [`Registry`]: each task resolves its own
    /// entry (a reserved `exp` row parameter, the run-level
    /// [`Memento::exp`] selection, or the registry's default), so one run
    /// — on any backend — can mix experiments in a single matrix.
    pub fn with_registry(registry: Registry) -> Memento {
        Memento {
            registry: Arc::new(registry),
            exp: None,
            options: RunOptions::default(),
            cache: None,
            store: None,
            checkpoint_dir: None,
            notifier: None,
            metrics: Arc::new(RunMetrics::new()),
            journal: None,
            worker_args: None,
            auth_token: None,
            #[cfg(unix)]
            pool: None,
            inflight: None,
            run_label: None,
        }
    }

    // ---- builder ----------------------------------------------------------

    /// Worker-thread count for the thread backend (min 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.options.workers = n.max(1);
        self
    }

    /// Aborts the run after the first failed task.
    pub fn fail_fast(mut self, yes: bool) -> Self {
        self.options.fail_fast = yes;
        self
    }

    /// Picks the execution tier (thread pool vs isolated worker
    /// processes). See [`ExecBackend`] for the trade-off.
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.options.backend = backend;
        self
    }

    /// Shorthand for [`Memento::backend`] with
    /// [`ExecBackend::Processes`]: run tasks in `workers` isolated
    /// processes, respawning a crashed worker up to `crash_budget` times
    /// per slot.
    pub fn isolate_processes(self, workers: usize, crash_budget: u32) -> Self {
        self.backend(ExecBackend::Processes { workers: workers.max(1), crash_budget })
    }

    /// Overrides the argument vector used to spawn worker processes
    /// (process backend only). The default re-uses the current process's
    /// own arguments, which is right for binaries whose `main` reaches
    /// `Memento::run` again when re-executed; test binaries instead pass a
    /// libtest filter selecting a worker-entry `#[test]` function.
    pub fn worker_args(mut self, args: Vec<String>) -> Self {
        self.worker_args = Some(args);
        self
    }

    /// Shorthand for [`Memento::backend`] with [`ExecBackend::Remote`]:
    /// listen for standing remote workers at `addr` (`host:port`) and run
    /// tasks over up to `workers` concurrent leases. Requires
    /// [`Memento::auth_token`] (or an existing pool via
    /// [`Memento::with_worker_pool`], which owns its own token).
    pub fn remote_workers(self, addr: impl Into<String>, workers: usize) -> Self {
        self.backend(ExecBackend::Remote {
            addr: addr.into(),
            workers: workers.max(1),
            task_timeout: None,
        })
    }

    /// Sets the shared token remote workers must present when they
    /// register (see [`crate::ipc::pool`] for the trust model). Only
    /// meaningful with [`ExecBackend::Remote`].
    pub fn auth_token(mut self, token: impl Into<String>) -> Self {
        self.auth_token = Some(token.into());
        self
    }

    /// Leases workers from an existing standing
    /// [`crate::ipc::pool::WorkerPool`] instead of binding a fresh
    /// listener. The pool outlives the run — hand the same handle to
    /// consecutive runs and the registered worker processes are reused,
    /// amortizing their spawn cost across many small runs.
    #[cfg(unix)]
    pub fn with_worker_pool(mut self, pool: Arc<crate::ipc::pool::WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Caps each task attempt's wall-clock time on the process/remote
    /// backends: an attempt still running after `budget` is stopped,
    /// journaled as a timeout ([`crate::coordinator::journal::Event::TaskTimedOut`]),
    /// and requeued under the run's [`RetryPolicy`] — without consuming
    /// worker crash budget. The thread backend ignores this (a running
    /// closure cannot be stopped safely in-process).
    pub fn task_timeout(mut self, budget: Duration) -> Self {
        self.options.task_timeout = Some(budget);
        self
    }

    /// Picks the [`Run`] event-channel buffering policy. The default is
    /// [`ChannelPolicy::Unbounded`] (the original `launch()` semantics).
    pub fn event_channel(mut self, policy: ChannelPolicy) -> Self {
        self.options.events = policy;
        self
    }

    /// Shorthand for [`Memento::event_channel`] with
    /// [`ChannelPolicy::Bounded`]: cap the live event buffer at
    /// `capacity` undelivered events. Terminal events are never dropped
    /// (their senders block under pressure); intermediate
    /// `Progress`/`TaskProgress` events are coalesced and counted on
    /// [`RunSummary::events_coalesced`].
    pub fn event_capacity(self, capacity: usize) -> Self {
        self.event_channel(ChannelPolicy::Bounded { capacity: capacity.max(1) })
    }

    /// Selects the named experiment every task targets by default (rows
    /// can still override it with their own reserved `exp` parameter).
    /// The name is validated against the registry at launch; an unknown
    /// name is a configuration error. On the CLI: `--exp NAME`.
    pub fn exp(mut self, name: impl Into<String>) -> Self {
        self.exp = Some(name.into());
        self
    }

    /// The experiment registry this run resolves tasks against.
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// Experiment-code version; changing it invalidates cached results.
    pub fn version(mut self, v: impl Into<String>) -> Self {
        self.options.version = v.into();
        self
    }

    /// Base RNG seed; per-task seeds derive from it and the task id.
    pub fn seed(mut self, seed: u64) -> Self {
        self.options.seed = seed;
        self
    }

    /// In-run retry policy for failed attempts (and worker crashes /
    /// task timeouts on the IPC backends).
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        self.options.retry = policy;
        self
    }

    /// Enables the on-disk result cache. New entries use the configured
    /// [`Memento::wire_format`] (call that first if you want JSON);
    /// existing entries are read back whatever their format.
    pub fn with_cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache = Some(Arc::new(
            ResultCache::open(dir.into())
                .expect("open cache dir")
                .storage_format(self.options.wire),
        ));
        self
    }

    /// Enables the cache with an existing handle (shared across runs).
    pub fn with_cache(mut self, cache: Arc<ResultCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Enables the **cross-run result database** at `dir` (see
    /// [`crate::store`]): results are cached as records in one shared
    /// segment-log store instead of per-run files, so consecutive runs of
    /// the same grid restore each other's results, and `memento query`
    /// answers parameter predicates across every run that used the store.
    /// When a checkpoint dir is also configured, its manifest and
    /// completion entries live in the store too (keyed by the dir name),
    /// unless a legacy `manifest.json` already exists there — old run
    /// directories keep resuming unchanged. On the CLI: `--store-dir`.
    pub fn store_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.store = Some(ResultStore::open(dir.into()).expect("open result store"));
        self
    }

    /// Enables the cross-run result database with an existing handle
    /// (shared across runs and threads).
    pub fn with_store(mut self, store: Arc<ResultStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Enables run checkpointing under this directory.
    pub fn with_checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Manifest flush interval, in completed tasks (min 1).
    pub fn checkpoint_flush_every(mut self, n: usize) -> Self {
        self.options.checkpoint_flush_every = n.max(1);
        self
    }

    /// Installs a notification provider (run started/finished, failures).
    pub fn with_notifier(mut self, n: Box<dyn NotificationProvider>) -> Self {
        self.notifier = Some(Arc::from(n));
        self
    }

    /// Installs a shared notification provider handle.
    pub fn with_shared_notifier(mut self, n: Arc<dyn NotificationProvider>) -> Self {
        self.notifier = Some(n);
        self
    }

    /// Prints progress lines at this interval.
    pub fn progress_every(mut self, d: Duration) -> Self {
        self.options.progress_interval = Some(d);
        self
    }

    /// Chooses the payload encoding for IPC frames and at-rest documents:
    /// tagged binary (the default, compact and fast to scan) or JSON
    /// (human-debuggable; also what pre-v3 remote workers are spoken
    /// to automatically). Reads auto-detect per payload, so switching
    /// formats between runs over the same directories is always safe.
    /// On the CLI: `--wire json|binary`.
    pub fn wire_format(mut self, format: WireFormat) -> Self {
        self.options.wire = format;
        if let Some(cache) = self.cache.take() {
            // Re-apply to a cache opened by an earlier builder call so
            // argument order doesn't matter; shared handles passed via
            // `with_cache` keep their own configuration.
            self.cache = Some(match Arc::try_unwrap(cache) {
                Ok(owned) => Arc::new(owned.storage_format(format)),
                Err(shared) => shared,
            });
        }
        self
    }

    /// Enables span tracing: every task attempt's state timeline is
    /// recorded (across all three backends — worker-side timestamps on
    /// the process/remote tiers are clock-mapped onto one merged
    /// timeline) and written to `<dir>/trace.jsonl` in the configured
    /// wire format. The final [`crate::obs::snapshot::MetricsSnapshot`]
    /// lands beside it for `memento status`. Analyze afterwards with
    /// `memento trace summarize <dir>` or export to Perfetto with
    /// `memento trace export <dir> --format chrome`.
    pub fn trace_to(mut self, dir: impl Into<PathBuf>) -> Self {
        self.options.trace_dir = Some(dir.into());
        self
    }

    /// Emits a live [`crate::obs::snapshot::MetricsSnapshot`] as
    /// [`RunEvent::Telemetry`] every `interval` (counters, timing
    /// percentiles, queue depth, observed rate, per-worker fleet state).
    pub fn telemetry_every(mut self, interval: Duration) -> Self {
        self.options.telemetry_every = Some(interval);
        self
    }

    /// Enables the append-only JSONL event journal at `path`.
    pub fn with_journal(mut self, path: impl Into<PathBuf>) -> Self {
        self.journal = Some(Arc::new(
            Journal::open(path.into()).expect("open journal file"),
        ));
        self
    }

    /// The run's shared metrics registry (readable during and after runs).
    pub fn metrics(&self) -> Arc<RunMetrics> {
        Arc::clone(&self.metrics)
    }

    /// The configured result-cache handle, if any.
    pub fn cache_handle(&self) -> Option<Arc<ResultCache>> {
        self.cache.clone()
    }

    /// The configured cross-run store handle, if any.
    pub fn store_handle(&self) -> Option<Arc<ResultStore>> {
        self.store.clone()
    }

    /// Installs a shared [`InflightGate`] so concurrent runs over one
    /// store execute each distinct task at most once **daemon-wide**: a
    /// run whose cache probe misses claims the task id before executing;
    /// a concurrent run hitting the same id parks until the claimant
    /// records its result, then restores it from the cache instead of
    /// executing. With a gate installed the supervised backends keep the
    /// shared cache in multi-writer mode (no exclusive-index switch) —
    /// the gate exists precisely because other writers are active.
    pub fn with_inflight_gate(mut self, gate: Arc<InflightGate>) -> Self {
        self.inflight = Some(gate);
        self
    }

    /// Overrides the cross-run store label for this run. The default is
    /// the checkpoint directory's name (or a fresh generated id); the
    /// daemon labels runs `tenant/run_id` so `memento query` can group
    /// and filter by tenant (see [`crate::store::tenant_label`]).
    pub fn run_label(mut self, label: impl Into<String>) -> Self {
        self.run_label = Some(label.into());
        self
    }

    // ---- execution ---------------------------------------------------------

    /// Expands the matrix and runs every included task, blocking until the
    /// last outcome. Creates a fresh checkpoint manifest when a checkpoint
    /// dir is configured.
    ///
    /// Preserved as a thin wrapper: `run()` ≡ `launch().collect()`.
    pub fn run(&self, matrix: &ConfigMatrix) -> Result<ResultSet, MementoError> {
        self.launch_inner(matrix, false)?.collect()
    }

    /// Resumes a checkpointed run: completed-successful tasks are restored
    /// from the manifest; failed and never-run tasks execute. Blocking,
    /// ≡ `launch_resume().collect()`.
    pub fn resume(&self, matrix: &ConfigMatrix) -> Result<ResultSet, MementoError> {
        self.launch_inner(matrix, true)?.collect()
    }

    /// Starts the run and returns a live [`Run`] handle immediately.
    ///
    /// The matrix is expanded **lazily** on the run's own thread — the
    /// full cartesian product is never materialized, so a 10¹²-combination
    /// matrix launches instantly and the first outcomes stream while
    /// expansion is still being consumed. Observe progress with
    /// [`Run::events`], stop mid-flight with [`Run::cancel`], and obtain
    /// the familiar [`ResultSet`] with [`Run::collect`].
    pub fn launch(&self, matrix: &ConfigMatrix) -> Result<Run, MementoError> {
        self.launch_inner(matrix, false)
    }

    /// [`Memento::launch`], but resuming from the configured checkpoint
    /// directory (the streaming form of [`Memento::resume`]).
    pub fn launch_resume(&self, matrix: &ConfigMatrix) -> Result<Run, MementoError> {
        self.launch_inner(matrix, true)
    }

    fn launch_inner(&self, matrix: &ConfigMatrix, resuming: bool) -> Result<Run, MementoError> {
        // Worker interception: when this process was spawned by a
        // supervisor (see `crate::ipc`), `run`/`launch` do not start a run
        // of their own — they serve task attempts over the socket with
        // this Memento's experiment function, then exit. This is what lets
        // a binary opt into process isolation with a single builder call:
        // a re-execution of itself flows back here and becomes a worker.
        #[cfg(unix)]
        {
            if crate::ipc::worker::active() {
                crate::ipc::worker::serve(Arc::clone(&self.registry))?;
                std::process::exit(0);
            }
        }
        crate::config::validate::validate(matrix)?;
        // A run-level experiment selection must name a registered entry;
        // surfacing this from `launch` (not per-task at dispatch) makes a
        // typo'd `.exp(..)` a configuration error, not a thousand typed
        // task failures.
        if let Some(name) = &self.exp {
            if self.registry.get(name).is_none() {
                return Err(MementoError::config(format!(
                    "exp(\"{name}\") names an unregistered experiment \
                     (registered: {})",
                    if self.registry.names().is_empty() {
                        "none".to_string()
                    } else {
                        self.registry.names().join(", ")
                    }
                )));
            }
        }

        // Cross-run store: register this run (label = explicit override,
        // else checkpoint dir name — that is the name `memento query
        // --last-runs` and store-backed resume key on) and align the
        // record encoding with the run's wire format.
        let run_label = self
            .run_label
            .clone()
            .or_else(|| {
                self.checkpoint_dir
                    .as_ref()
                    .and_then(|d| d.file_name())
                    .and_then(|n| n.to_str())
                    .map(|s| s.to_string())
            })
            .unwrap_or_else(fresh_run_id);
        if let Some(store) = &self.store {
            store.set_wire(self.options.wire);
            store
                .begin_run(&run_label)
                .map_err(|e| MementoError::storage(format!("register run in store: {e}")))?;
        }

        // Checkpoint setup stays synchronous so configuration errors
        // (missing dir, fingerprint/version mismatch) surface from
        // `launch` itself, not from a later `collect`. The final task
        // total is unknown until the lazy expansion is exhausted; the run
        // thread fills it in via `CheckpointStore::set_total`. With a
        // store configured, checkpoint records live in the store keyed by
        // the run label — except that a legacy `manifest.json` in the run
        // dir wins on resume, so pre-store run directories stay readable.
        let checkpoint: Option<Arc<CheckpointStore>> = match &self.checkpoint_dir {
            None => None,
            Some(dir) => {
                let fp = matrix.fingerprint();
                let flush_every = self.options.checkpoint_flush_every;
                let ck = match (&self.store, resuming) {
                    (Some(store), true) if !CheckpointStore::exists(dir) => {
                        CheckpointStore::resume_in_store(
                            Arc::clone(store),
                            &run_label,
                            dir,
                            &fp,
                            &self.options.version,
                            0,
                            flush_every,
                        )?
                    }
                    (Some(store), false) => {
                        let ck = CheckpointStore::create_in_store(
                            Arc::clone(store),
                            &run_label,
                            dir,
                            &fp,
                            &self.options.version,
                            0,
                            flush_every,
                        )?;
                        // A fresh store-backed run supersedes any legacy
                        // manifest left in the dir — otherwise a later
                        // resume would prefer the stale dir-mode state.
                        let _ = std::fs::remove_file(dir.join("manifest.json"));
                        ck
                    }
                    (_, true) => CheckpointStore::resume(
                        dir,
                        &fp,
                        &self.options.version,
                        0,
                        flush_every,
                    )?,
                    (_, false) => CheckpointStore::create(
                        dir,
                        &fp,
                        &self.options.version,
                        0,
                        flush_every,
                    )?,
                };
                let ck = ck.storage_format(self.options.wire);
                if resuming {
                    // Per-experiment version gate: a manifest that recorded
                    // entry versions refuses to resume under a registry
                    // whose shared entries drifted (the run-wide version
                    // check above can't see per-entry salts).
                    ck.verify_exps(&self.registry.versions())?;
                }
                Some(Arc::new(ck.with_exps(self.registry.versions())))
            }
        };
        if resuming && checkpoint.is_none() {
            return Err(MementoError::config(
                "resume() requires with_checkpoint_dir(..)",
            ));
        }

        // Effective cache: an explicit cache handle wins; otherwise a
        // configured store backs a store-mode cache, giving every backend
        // the cross-run restore path with no other code changes.
        let cache = self.cache.clone().or_else(|| {
            self.store.as_ref().map(|store| {
                Arc::new(
                    ResultCache::open_store(Arc::clone(store))
                        .storage_format(self.options.wire),
                )
            })
        });

        let (sink, rx) = Run::channel(self.options.events);
        let cancel = Arc::new(AtomicBool::new(false));
        let worker = RunWorker {
            registry: Arc::clone(&self.registry),
            exp: self.exp.clone(),
            options: self.options.clone(),
            cache,
            notifier: self.notifier.clone(),
            metrics: Arc::clone(&self.metrics),
            journal: self.journal.clone(),
            worker_args: self.worker_args.clone(),
            auth_token: self.auth_token.clone(),
            #[cfg(unix)]
            pool: self.pool.clone(),
            inflight: self.inflight.clone(),
            run_label,
            checkpoint,
            matrix: matrix.clone(),
            resuming,
            sink,
            cancel: Arc::clone(&cancel),
        };
        let handle = std::thread::Builder::new()
            .name("memento-run".into())
            .spawn(move || worker.execute())
            .map_err(|e| MementoError::config(format!("spawn run thread: {e}")))?;
        Ok(Run::new(rx, cancel, handle))
    }
}

/// One launched run, moved onto its own thread by [`Memento::launch`].
///
/// Owns clones of the `Memento` configuration so the builder, the [`Run`]
/// handle, and the executing run are fully decoupled. Everything the run
/// observes flows out through the event sink (typed [`RunEvent`]s), the
/// gated notifier, and the shared metrics registry.
struct RunWorker {
    registry: Arc<Registry>,
    /// Validated run-level experiment selection (see [`Memento::exp`]).
    exp: Option<String>,
    options: RunOptions,
    cache: Option<Arc<ResultCache>>,
    notifier: Option<Arc<dyn NotificationProvider>>,
    metrics: Arc<RunMetrics>,
    journal: Option<Arc<Journal>>,
    worker_args: Option<Vec<String>>,
    auth_token: Option<String>,
    #[cfg(unix)]
    pool: Option<Arc<crate::ipc::pool::WorkerPool>>,
    /// Cross-run execute-once gate (see [`InflightGate`]), when installed.
    inflight: Option<Arc<InflightGate>>,
    /// The store label this run registered under — also the claim owner
    /// recorded in the in-flight gate.
    run_label: String,
    checkpoint: Option<Arc<CheckpointStore>>,
    matrix: ConfigMatrix,
    resuming: bool,
    sink: EventSink,
    cancel: Arc<AtomicBool>,
}

/// Which supervised (IPC) worker source a dispatch uses — the owned
/// remainder of an [`ExecBackend::Processes`]/[`ExecBackend::Remote`]
/// variant, threaded into [`RunWorker::run_supervised`].
enum SupervisedKind {
    /// Spawn `workers` local worker processes (crash budget per slot).
    Spawn { workers: usize, crash_budget: u32 },
    /// Lease up to `workers` standing remote workers (bind a listener at
    /// `addr` unless an existing pool was installed).
    Remote { addr: String, workers: usize, task_timeout: Option<Duration> },
}

impl RunWorker {
    /// The streaming run pipeline. Expansion, restore-probing, execution,
    /// and observation are one lazy stream: the scheduler pulls specs from
    /// the planner (which restores cache/checkpoint hits as it scans and
    /// never materializes the product), outcomes are pushed out as typed
    /// events the moment they complete, and totals are finalized when the
    /// expansion is first exhausted.
    fn execute(self) -> Result<ResultSet, MementoError> {
        let wall = Stopwatch::start();
        let version = self.options.version.clone();
        let settings = Arc::new(self.matrix.settings.clone());

        // Wind-down sweep for the cross-run gate: whatever exit path this
        // run takes (including error returns above the normal release
        // points), claims it still holds are released so concurrent runs
        // parked on them make progress.
        let _gate_guard = self
            .inflight
            .as_ref()
            .map(|g| g.run_guard(&self.run_label));

        // Observability: the tracer (when `trace_dir` is set) records every
        // attempt's span timeline; `FleetStats` aggregates per-worker
        // liveness and completions for telemetry snapshots. Both are `None`
        // unless asked for — the disabled paths are a skipped Option check.
        let tracer: Option<Arc<Tracer>> = match &self.options.trace_dir {
            None => None,
            Some(dir) => match Tracer::create(dir, self.options.wire) {
                Ok(t) => Some(Arc::new(t)),
                Err(e) => {
                    // `RunComplete` is documented as always the terminal
                    // event, so emit an empty summary before erroring.
                    self.sink.emit(RunEvent::RunComplete(RunSummary {
                        total: 0,
                        succeeded: 0,
                        failed: 0,
                        from_cache: 0,
                        skipped: 0,
                        wall_secs: wall.elapsed_secs(),
                        events_coalesced: self.sink.coalesced_count(),
                        aborted: true,
                        cancelled: false,
                        metrics: None,
                    }));
                    return Err(MementoError::storage(format!("create trace dir: {e}")));
                }
            },
        };
        let fleet: Option<Arc<FleetStats>> =
            (self.options.trace_dir.is_some() || self.options.telemetry_every.is_some())
                .then(|| Arc::new(FleetStats::new()));

        // Notification ordering gate: `RunStarted` carries exact totals,
        // which a streaming run only knows once the expansion is
        // exhausted. Task-level notifications raised before that moment
        // are buffered behind it (see [`GatedNotifier`]).
        let gate = self.notifier.clone().map(GatedNotifier::new);
        let notifier: Option<Arc<dyn NotificationProvider>> = gate
            .clone()
            .map(|g| g as Arc<dyn NotificationProvider>);

        let progress = ProgressState::streaming();
        let _reporter = self
            .options
            .progress_interval
            .map(|iv| ProgressReporter::start(Arc::clone(&progress), iv, false));

        // Live-telemetry sampler: a park-based loop (so the final join is
        // prompt) that captures a MetricsSnapshot each interval and emits
        // it as a coalescable Telemetry event.
        let run_start = std::time::Instant::now();
        let telemetry_stop = Arc::new(AtomicBool::new(false));
        let telemetry = self.options.telemetry_every.and_then(|iv| {
            let stop = Arc::clone(&telemetry_stop);
            let sink = self.sink.clone();
            let metrics = Arc::clone(&self.metrics);
            let progress = Arc::clone(&progress);
            let fleet = fleet.clone();
            std::thread::Builder::new()
                .name("memento-telemetry".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        std::thread::park_timeout(iv);
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        sink.emit(RunEvent::Telemetry(MetricsSnapshot::capture(
                            &metrics,
                            Some(&*progress),
                            fleet.as_deref(),
                            run_start.elapsed().as_secs_f64(),
                        )));
                    }
                })
                .ok()
        });

        let outcomes: Arc<Mutex<Vec<TaskOutcome>>> = Arc::new(Mutex::new(Vec::new()));
        let restored = Arc::new(AtomicUsize::new(0));
        let finished = Arc::new(AtomicUsize::new(0));
        let skipped_ctr = Arc::new(AtomicUsize::new(0));

        let progress_event: Arc<dyn Fn() + Send + Sync> = {
            let sink = self.sink.clone();
            let progress = Arc::clone(&progress);
            let restored = Arc::clone(&restored);
            let finished = Arc::clone(&finished);
            let skipped_ctr = Arc::clone(&skipped_ctr);
            Arc::new(move || {
                sink.emit(RunEvent::Progress {
                    finished: finished.load(Ordering::SeqCst),
                    restored: restored.load(Ordering::SeqCst),
                    skipped: skipped_ctr.load(Ordering::SeqCst),
                    planned: progress.total(),
                    planning_complete: progress.planning_complete(),
                });
            })
        };

        // Terminal-outcome fan-in shared by both backends: accumulate for
        // the final ResultSet, publish TaskFinished + Progress events.
        let deliver: Arc<dyn Fn(TaskOutcome) + Send + Sync> = {
            let outcomes = Arc::clone(&outcomes);
            let finished = Arc::clone(&finished);
            let sink = self.sink.clone();
            let progress_event = Arc::clone(&progress_event);
            Arc::new(move |o: TaskOutcome| {
                finished.fetch_add(1, Ordering::SeqCst);
                sink.emit(RunEvent::TaskFinished(o.clone()));
                outcomes.lock().unwrap().push(o);
                progress_event();
            })
        };
        let deliver_restored: Arc<dyn Fn(TaskOutcome) + Send + Sync> = {
            let outcomes = Arc::clone(&outcomes);
            let restored = Arc::clone(&restored);
            let sink = self.sink.clone();
            let progress = Arc::clone(&progress);
            let progress_event = Arc::clone(&progress_event);
            Arc::new(move |o: TaskOutcome| {
                restored.fetch_add(1, Ordering::SeqCst);
                progress.mark_restored();
                sink.emit(RunEvent::TaskFinished(o.clone()));
                outcomes.lock().unwrap().push(o);
                progress_event();
            })
        };

        // The planner, split into the two stages `DrainOnceSource` keeps
        // apart so a resume of a mostly-complete run restores N-way
        // parallel:
        //
        // - the **raw source** is the bare lazy expansion — the only code
        //   that ever runs under the scheduler/supervisor source mutex;
        // - the **restore filter** screens each pulled spec against the
        //   resumed manifest and the result cache (cache probe, checkpoint
        //   record, restored-outcome delivery — all I/O) on the pulling
        //   worker's own thread, outside that mutex, merging restored
        //   outcomes back through `deliver_restored` exactly once.
        //
        // A restored task becomes a TaskFinished event without ever
        // entering the execution queue.
        // Experiment annotation: every spec leaving the expansion carries
        // its resolved [`ExpRef`] before anything hashes it, so cache
        // probes, checkpoint records, and dispatch all see one identity.
        // The precedence (row `exp` param → run-level `.exp(..)` →
        // registry default) lives in [`Registry::annotate_spec`], shared
        // with `memento expand`.
        let raw_source: SpecSource = {
            let registry = Arc::clone(&self.registry);
            let run_exp = self.exp.clone();
            let run_version = version.clone();
            Box::new(
                expand::Expansion::new(self.matrix.clone()).map(move |spec| {
                    registry.annotate_spec(spec, run_exp.as_deref(), &run_version)
                }),
            )
        };
        // First storage error hit by the restore filter (it runs inside
        // the pull path and cannot propagate `?` directly); surfaced after
        // dispatch so checkpoint write failures still fail the run, as
        // the eager pipeline's `ck.record(..)?` did.
        let planner_error: Arc<Mutex<Option<MementoError>>> = Arc::new(Mutex::new(None));
        let restore_filter: SpecFilter = {
            let cache = self.cache.clone();
            let checkpoint = self.checkpoint.clone();
            let metrics = Arc::clone(&self.metrics);
            let journal = self.journal.clone();
            let progress = Arc::clone(&progress);
            let version = version.clone();
            let resuming = self.resuming;
            let deliver_restored = Arc::clone(&deliver_restored);
            let planner_error = Arc::clone(&planner_error);
            let tracer = tracer.clone();
            let inflight = self.inflight.clone();
            let run_label = self.run_label.clone();
            let cancel = Arc::clone(&self.cancel);
            Arc::new(move |spec: TaskSpec| {
                // A restored task never executes; its timeline is three
                // instantaneous states on the pulling worker's thread,
                // with attempt 0 marking "no execution happened".
                let trace_restored = |spec: &TaskSpec| {
                    if let Some(t) = &tracer {
                        t.record(spec.index, 0, SpanState::Queued, None, Some(spec.label()));
                        t.record(spec.index, 0, SpanState::Restored, None, None);
                        t.record(spec.index, 0, SpanState::Recorded, None, None);
                    }
                };
                let id = spec.id(&version);
                // (a) resumed manifest
                if resuming {
                    if let Some(entry) = checkpoint.as_ref().and_then(|ck| ck.entry(&id)) {
                        if entry.succeeded() {
                            metrics.tasks_cached.inc();
                            trace_restored(&spec);
                            deliver_restored(TaskOutcome {
                                spec,
                                id,
                                status: TaskStatus::Success,
                                value: entry.value,
                                failure: None,
                                duration_secs: 0.0,
                                from_cache: true,
                                attempts: 0,
                            });
                            return None;
                        }
                        // failed previously -> re-run
                    }
                }
                // (b) result cache, interleaved with the cross-run gate.
                // Without a gate this is one probe (the pre-daemon
                // behavior). With a gate installed, a miss must *claim*
                // the id before the spec may execute; finding it claimed
                // by another run parks here and re-probes on wake-up —
                // the claimant records its result before releasing, so
                // the post-wake probe restores instead of re-executing.
                let mut first_probe = true;
                loop {
                    if let Some(cache) = &cache {
                        if let Some(value) = cache.get(&id) {
                            metrics.cache_hits.inc();
                            // Also record into the (fresh) checkpoint so a
                            // later resume sees it without consulting the
                            // cache.
                            if let Some(ck) = &checkpoint {
                                if let Err(e) = ck.record(&id, Some(&value), None, 0.0, 0) {
                                    let mut slot = planner_error.lock().unwrap();
                                    slot.get_or_insert(e);
                                }
                            }
                            if let Some(j) = &journal {
                                j.record(&Event::TaskRestored { id: id.clone() });
                            }
                            metrics.tasks_cached.inc();
                            trace_restored(&spec);
                            deliver_restored(TaskOutcome {
                                spec,
                                id,
                                status: TaskStatus::Success,
                                value: Some(value),
                                failure: None,
                                duration_secs: 0.0,
                                from_cache: true,
                                attempts: 0,
                            });
                            return None;
                        }
                        if first_probe {
                            metrics.cache_misses.inc();
                            first_probe = false;
                        }
                    }
                    match &inflight {
                        None => break,
                        Some(gate) => match gate.try_claim(&id.0, &run_label) {
                            Claim::Claimed => break,
                            Claim::InFlightElsewhere => {
                                // A cancelled run stops parking and lets
                                // the spec through unclaimed; dispatch
                                // skips it on the cancel check, and the
                                // owner-checked release keeps the other
                                // run's claim intact either way.
                                if cancel.load(Ordering::SeqCst) {
                                    break;
                                }
                                gate.wait_released(&id.0, Duration::from_millis(200));
                            }
                        },
                    }
                }
                progress.add_planned(1);
                Some(spec)
            })
        };

        // Fires once, when the raw expansion is exhausted AND every pulled
        // spec has cleared the restore filter (the source's outstanding
        // lease count guarantees the merge): totals become final, the
        // checkpoint learns them, and the gate releases `RunStarted`
        // (with exact counts) ahead of any buffered failures.
        let drained_hook: Box<dyn FnOnce() + Send + Sync> = {
            let progress = Arc::clone(&progress);
            let restored = Arc::clone(&restored);
            let checkpoint = self.checkpoint.clone();
            let gate = gate.clone();
            let progress_event = Arc::clone(&progress_event);
            Box::new(move || {
                progress.finish_planning();
                let from_cache = restored.load(Ordering::SeqCst);
                let total = progress.total() + from_cache;
                if let Some(ck) = &checkpoint {
                    ck.set_total(total);
                }
                if let Some(g) = &gate {
                    g.open(total, from_cache);
                }
                // A Progress event with final totals, so observers see
                // `planning_complete` even if the last outcome landed
                // before exhaustion was discovered.
                progress_event();
            })
        };

        // -- dispatch over the selected backend ----------------------------
        // Cloned out so the match arms can consume the variant's fields
        // (`Remote.addr`) while the arms' bodies still borrow `self`.
        let backend = self.options.backend.clone();
        let dispatched: Result<(bool, bool, usize, bool), MementoError> = match backend {
            ExecBackend::Threads => {
                let job = self.make_job(
                    Arc::clone(&settings),
                    self.checkpoint.clone(),
                    version.clone(),
                    notifier.clone(),
                    tracer.clone(),
                );
                let sched = SchedulerOptions {
                    workers: self.options.workers,
                    fail_fast: self.options.fail_fast,
                };
                let report = crate::coordinator::scheduler::run_stream(
                    raw_source,
                    &sched,
                    job,
                    StreamHooks {
                        on_outcome: Some(Arc::clone(&deliver)),
                        on_skip: Some({
                            let skipped_ctr = Arc::clone(&skipped_ctr);
                            Arc::new(move |_s: TaskSpec| {
                                skipped_ctr.fetch_add(1, Ordering::SeqCst);
                            })
                        }),
                        restore_filter: Some(restore_filter),
                        on_source_drained: Some(drained_hook),
                        progress: Some(Arc::clone(&progress)),
                        metrics: Some(Arc::clone(&self.metrics)),
                        cancel: Some(Arc::clone(&self.cancel)),
                        fleet: fleet.clone(),
                    },
                );
                Ok((report.aborted, report.cancelled, report.skipped, report.drain_truncated))
            }
            ExecBackend::Processes { workers, crash_budget } => self.run_supervised(
                raw_source,
                restore_filter,
                &settings,
                version.clone(),
                Arc::clone(&progress),
                SupervisedKind::Spawn { workers, crash_budget },
                Arc::clone(&deliver),
                Arc::clone(&skipped_ctr),
                drained_hook,
                notifier.clone(),
                tracer.clone(),
                fleet.clone(),
            ),
            ExecBackend::Remote { addr, workers, task_timeout } => self.run_supervised(
                raw_source,
                restore_filter,
                &settings,
                version.clone(),
                Arc::clone(&progress),
                SupervisedKind::Remote { addr, workers, task_timeout },
                Arc::clone(&deliver),
                Arc::clone(&skipped_ctr),
                drained_hook,
                notifier.clone(),
                tracer.clone(),
                fleet.clone(),
            ),
        };
        // Stop the telemetry sampler before any terminal event is emitted:
        // `RunComplete` is documented as the last event on the channel.
        telemetry_stop.store(true, Ordering::SeqCst);
        if let Some(handle) = telemetry {
            handle.thread().unpark();
            let _ = handle.join();
        }

        let (aborted, cancelled, skipped_count, drain_truncated) = match dispatched {
            Ok(t) => t,
            Err(e) => {
                // Backend setup failed (e.g. IPC socket/spawn errors).
                // `RunComplete` is documented as always the terminal
                // event, so emit a best-effort summary before erroring.
                let results = outcomes.lock().unwrap();
                let succeeded = results.iter().filter(|o| o.succeeded()).count();
                let failed = results.len() - succeeded;
                let from_cache = restored.load(Ordering::SeqCst);
                self.sink.emit(RunEvent::RunComplete(RunSummary {
                    total: progress.total() + from_cache,
                    succeeded,
                    failed,
                    from_cache,
                    skipped: skipped_ctr.load(Ordering::SeqCst),
                    wall_secs: wall.elapsed_secs(),
                    events_coalesced: self.sink.coalesced_count(),
                    aborted: true,
                    cancelled: false,
                    metrics: None,
                }));
                if let Some(t) = &tracer {
                    let _ = t.finish(); // best-effort footer on the abort path
                }
                return Err(e);
            }
        };

        // -- final checkpoint flush ----------------------------------------
        // Storage failures (final flush, or a planner-side checkpoint
        // record error) fail the run, but only after `RunComplete` is
        // emitted below — it is documented as always the terminal event.
        let storage_result: Result<(), MementoError> = (|| {
            if let Some(ck) = &self.checkpoint {
                ck.flush()?;
                self.metrics.checkpoint_flushes.inc();
            }
            // Seal the trace: joins the sink thread and writes the footer
            // (span/drop totals) readers use to verify completeness.
            if let Some(t) = &tracer {
                t.finish()
                    .map_err(|e| MementoError::storage(format!("finalize trace: {e}")))?;
            }
            match planner_error.lock().unwrap().take() {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })();

        let results = ResultSet::new(std::mem::take(&mut *outcomes.lock().unwrap()));
        let from_cache = restored.load(Ordering::SeqCst);
        let total = progress.total() + from_cache;
        let succeeded = results.successes().count();
        let failed = results.n_failed();

        // Final telemetry snapshot: carried on the terminal event (and
        // thus the CLI's `run_complete` ndjson line) and persisted beside
        // the trace for `memento status`.
        let final_metrics = MetricsSnapshot::capture(
            &self.metrics,
            Some(&*progress),
            fleet.as_deref(),
            wall.elapsed_secs(),
        );
        if let Some(dir) = &self.options.trace_dir {
            let _ = write_snapshot(dir, &final_metrics, self.options.wire);
        }
        if storage_result.is_ok() {
            if let Some(g) = &gate {
                // A run cancelled before planning finished never opened
                // the gate; flush so buffered task notifications still
                // land before the terminal one.
                g.flush();
                g.notify(&Notification::RunFinished {
                    total,
                    succeeded,
                    failed,
                    from_cache,
                    wall_secs: wall.elapsed_secs(),
                });
            }
        }
        // All emitting workers are joined by now, so the coalesced count
        // carried on the terminal event is exact.
        self.sink.emit(RunEvent::RunComplete(RunSummary {
            total,
            succeeded,
            failed,
            from_cache,
            skipped: skipped_count,
            wall_secs: wall.elapsed_secs(),
            events_coalesced: self.sink.coalesced_count(),
            aborted,
            cancelled,
            metrics: Some(final_metrics),
        }));

        storage_result?;
        if aborted {
            // `drain_truncated` means the post-abort skip accounting gave
            // up before enumerating the (astronomically large) remainder.
            let at_least = if drain_truncated { "at least " } else { "" };
            return Err(MementoError::Aborted(format!(
                "fail-fast stopped the run after {failed} failure(s); \
                 {at_least}{skipped_count} task(s) were skipped"
            )));
        }
        Ok(results)
    }

    /// Dispatches the spec stream over supervised worker connections —
    /// spawned processes ([`ExecBackend::Processes`]) or leased standing
    /// remote workers ([`ExecBackend::Remote`]); see [`crate::ipc`]. The
    /// supervisor owns journal/metrics/progress accounting per attempt and
    /// pulls lazily from the same raw expansion + restore filter the
    /// thread backend uses (the filter runs on its slot threads, outside
    /// the source mutex); the `record` hook below owns the persistence
    /// pipeline (cache, checkpoint, failure notification) and feeds every
    /// terminal outcome into the run's event channel via `deliver`.
    #[cfg(unix)]
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn run_supervised(
        &self,
        source: SpecSource,
        restore_filter: SpecFilter,
        settings: &std::collections::BTreeMap<String, Json>,
        version: String,
        progress: Arc<ProgressState>,
        kind: SupervisedKind,
        deliver: Arc<dyn Fn(TaskOutcome) + Send + Sync>,
        skipped_ctr: Arc<AtomicUsize>,
        drained_hook: Box<dyn FnOnce() + Send + Sync>,
        notifier: Option<Arc<dyn NotificationProvider>>,
        tracer: Option<Arc<Tracer>>,
        fleet: Option<Arc<FleetStats>>,
    ) -> Result<(bool, bool, usize, bool), MementoError> {
        use crate::ipc::pool::{PoolOptions, WorkerPool};
        use crate::ipc::supervisor::{self, SupervisorHooks, SupervisorOptions, WorkerSource};
        use crate::ipc::transport::Transport;

        // Resolve the worker source first so configuration errors (e.g. a
        // TCP bind failure, or a remote backend without a token) surface
        // before the cache is switched into exclusive mode.
        let (workers, crash_budget, task_timeout, worker_source) = match kind {
            SupervisedKind::Spawn { workers, crash_budget } => (
                workers,
                crash_budget,
                self.options.task_timeout,
                WorkerSource::Spawn,
            ),
            SupervisedKind::Remote { addr, workers, task_timeout } => {
                let pool = match &self.pool {
                    Some(pool) => Arc::clone(pool),
                    None => {
                        if self.auth_token.is_none() {
                            return Err(MementoError::config(
                                "the remote backend requires auth_token(..) (or an \
                                 existing pool via with_worker_pool(..)): TCP workers \
                                 must authenticate",
                            ));
                        }
                        WorkerPool::listen(
                            &Transport::Tcp { bind: addr },
                            PoolOptions {
                                token: self.auth_token.clone(),
                                ..PoolOptions::default()
                            },
                        )?
                    }
                };
                (
                    workers,
                    // Pool budgets count *consecutive* losses per slot and
                    // reset on progress (see the supervisor docs), so the
                    // default depth is enough headroom for churn.
                    SupervisorOptions::default().crash_budget,
                    task_timeout.or(self.options.task_timeout),
                    WorkerSource::Pool(pool),
                )
            }
        };

        // Workers never write the store directly — for the duration of
        // this dispatch the supervisor is the single writer, so the cache
        // index is authoritative and cold misses can skip their per-id
        // disk probe. The previous mode is restored afterwards: a shared
        // handle must not lose its documented multi-writer tolerance for
        // later runs just because one run used process isolation.
        //
        // With a cross-run gate installed the premise is false — the
        // daemon's other concurrent runs write the same cache handle —
        // so the switch is suppressed entirely.
        let prev_exclusive = if self.inflight.is_some() {
            None
        } else {
            self.cache.as_ref().map(|c| {
                let prev = c.is_exclusive();
                c.set_exclusive(true);
                prev
            })
        };

        let mut opts = SupervisorOptions {
            workers: workers.max(1),
            crash_budget,
            retry: self.options.retry,
            fail_fast: self.options.fail_fast,
            version,
            run_seed: self.options.seed,
            task_timeout,
            wire: self.options.wire,
            ..SupervisorOptions::default()
        };
        if let Some(args) = &self.worker_args {
            opts.worker_args = args.clone();
        }

        let save_progress = self.checkpoint.as_ref().map(|ck| {
            let ck = Arc::clone(ck);
            Arc::new(move |tid: &TaskId, j: &Json| ck.save_progress(tid, j))
                as Arc<dyn Fn(&TaskId, &Json) + Send + Sync>
        });
        let load_progress = self.checkpoint.as_ref().map(|ck| {
            let ck = Arc::clone(ck);
            Arc::new(move |tid: &TaskId| ck.load_progress(tid))
                as Arc<dyn Fn(&TaskId) -> Option<Json> + Send + Sync>
        });
        let record = {
            let cache = self.cache.clone();
            let checkpoint = self.checkpoint.clone();
            let notifier = notifier.clone();
            let deliver = Arc::clone(&deliver);
            let inflight = self.inflight.clone();
            let run_label = self.run_label.clone();
            Arc::new(move |o: &TaskOutcome| {
                match (&o.status, &o.value) {
                    (TaskStatus::Success, Some(v)) => {
                        if let Some(cache) = &cache {
                            let _ = cache.put(&o.id, &o.spec, v);
                        }
                        if let Some(ck) = &checkpoint {
                            let _ =
                                ck.record(&o.id, Some(v), None, o.duration_secs, o.attempts);
                            ck.clear_progress(&o.id);
                        }
                    }
                    _ => {
                        let message = o
                            .failure
                            .as_ref()
                            .map(|f| f.message.clone())
                            .unwrap_or_else(|| "unknown failure".to_string());
                        if let Some(ck) = &checkpoint {
                            let _ = ck.record(
                                &o.id,
                                None,
                                Some(&message),
                                o.duration_secs,
                                o.attempts,
                            );
                        }
                        if let (Some(n), Some(f)) = (&notifier, &o.failure) {
                            n.notify(&Notification::TaskFailed { failure: f.clone() });
                        }
                    }
                }
                // Release *after* recording: parked concurrent runs
                // re-probe the cache on wake-up and must see the value.
                if let Some(gate) = &inflight {
                    gate.release(&o.id.0, &run_label);
                }
                deliver(o.clone());
            }) as Arc<dyn Fn(&TaskOutcome) + Send + Sync>
        };

        let report = supervisor::run(
            source,
            settings.clone(),
            opts,
            SupervisorHooks {
                journal: self.journal.clone(),
                metrics: Some(Arc::clone(&self.metrics)),
                progress: Some(progress),
                save_progress,
                load_progress,
                record: Some(record),
                events: Some(self.sink.clone()),
                cancel: Some(Arc::clone(&self.cancel)),
                restore_filter: Some(restore_filter),
                on_source_drained: Some(drained_hook),
                tracer,
                fleet,
            },
            worker_source,
        );
        if let (Some(c), Some(prev)) = (&self.cache, prev_exclusive) {
            c.set_exclusive(prev);
        }
        let report = report?;
        skipped_ctr.fetch_add(report.skipped.len(), Ordering::SeqCst);
        Ok((
            report.aborted,
            report.cancelled,
            report.skipped.len(),
            report.drain_truncated,
        ))
    }

    /// The IPC tiers need Unix domain sockets and `fork`/`exec` process
    /// spawning; other platforms fall back to a clear error.
    #[cfg(not(unix))]
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn run_supervised(
        &self,
        _source: SpecSource,
        _restore_filter: SpecFilter,
        _settings: &std::collections::BTreeMap<String, Json>,
        _version: String,
        _progress: Arc<ProgressState>,
        _kind: SupervisedKind,
        _deliver: Arc<dyn Fn(TaskOutcome) + Send + Sync>,
        _skipped_ctr: Arc<AtomicUsize>,
        _drained_hook: Box<dyn FnOnce() + Send + Sync>,
        _notifier: Option<Arc<dyn NotificationProvider>>,
        _tracer: Option<Arc<Tracer>>,
        _fleet: Option<Arc<FleetStats>>,
    ) -> Result<(bool, bool, usize, bool), MementoError> {
        Err(MementoError::ipc(
            "ExecBackend::Processes / ExecBackend::Remote require a unix platform",
        ))
    }

    /// Builds the per-task closure: context construction, retry loop, panic
    /// capture, cache/checkpoint recording, metrics, failure notification,
    /// and `TaskStarted` event emission per attempt.
    fn make_job(
        &self,
        settings: Arc<std::collections::BTreeMap<String, Json>>,
        checkpoint: Option<Arc<CheckpointStore>>,
        version: String,
        notifier: Option<Arc<dyn NotificationProvider>>,
        tracer: Option<Arc<Tracer>>,
    ) -> crate::coordinator::scheduler::Job {
        let registry = Arc::clone(&self.registry);
        let cache = self.cache.clone();
        let metrics = Arc::clone(&self.metrics);
        let journal = self.journal.clone();
        let retry = self.options.retry;
        let run_seed = self.options.seed;
        let sink = self.sink.clone();
        let inflight = self.inflight.clone();
        let run_label = self.run_label.clone();

        Arc::new(move |spec: &TaskSpec| {
            let id = spec.id(&version);
            let seed = task_seed(run_seed, &id);
            let sw = Stopwatch::start();
            let worker = thread_worker_id();
            metrics.tasks_total.inc();

            // Resolve the task's experiment before anything runs. An
            // unknown name has no function to call: fail typed
            // immediately, skipping the retry loop (retrying cannot make
            // a registration appear).
            let exp_fn = match registry.resolve(spec.exp.as_ref()) {
                Ok(f) => f,
                Err(e) => {
                    metrics.tasks_failed.inc();
                    let failure = TaskFailure {
                        kind: FailureKind::UnknownExperiment,
                        message: e.to_string(),
                        params: spec.param_strings(),
                        attempts: 0,
                    };
                    if let Some(j) = &journal {
                        j.record(&Event::TaskFailed {
                            id: id.clone(),
                            attempt: 0,
                            message: failure.message.clone(),
                        });
                    }
                    if let Some(ck) = &checkpoint {
                        let _ = ck.record(&id, None, Some(&failure.message), 0.0, 0);
                    }
                    if let Some(n) = &notifier {
                        n.notify(&Notification::TaskFailed { failure: failure.clone() });
                    }
                    if let Some(gate) = &inflight {
                        gate.release(&id.0, &run_label);
                    }
                    return TaskOutcome {
                        spec: spec.clone(),
                        id,
                        status: TaskStatus::Failed,
                        value: None,
                        failure: Some(failure),
                        duration_secs: 0.0,
                        from_cache: false,
                        attempts: 0,
                    };
                }
            };

            let progress_sink: Option<Arc<dyn Fn(&TaskId, &Json) + Send + Sync>> =
                checkpoint.as_ref().map(|ck| {
                    let ck = Arc::clone(ck);
                    Arc::new(move |tid: &TaskId, j: &Json| ck.save_progress(tid, j))
                        as Arc<dyn Fn(&TaskId, &Json) + Send + Sync>
                });

            let mut attempt: u32 = 0;
            let mut last_failure: Option<TaskFailure> = None;
            let value: Option<Json> = loop {
                attempt += 1;
                if attempt > 1 {
                    metrics.tasks_retried.inc();
                    std::thread::sleep(retry.delay_before(attempt));
                }
                let restored_progress =
                    checkpoint.as_ref().and_then(|ck| ck.load_progress(&id));
                let ctx = TaskContext::new(
                    spec.clone(),
                    Arc::clone(&settings),
                    seed,
                    attempt,
                    id.clone(),
                    restored_progress,
                    progress_sink.clone(),
                );
                if let Some(j) = &journal {
                    j.record(&Event::TaskStarted { id: id.clone(), attempt });
                }
                sink.emit(RunEvent::TaskStarted {
                    index: spec.index,
                    id: id.clone(),
                    attempt,
                });
                if let Some(t) = &tracer {
                    // An in-process attempt has no separate dispatch hop:
                    // Queued and Dispatched collapse onto the worker
                    // thread's pickup, and exec brackets the closure call.
                    t.record(spec.index, attempt, SpanState::Queued, None, Some(spec.label()));
                    t.record(spec.index, attempt, SpanState::Dispatched, Some(worker), None);
                    t.record(spec.index, attempt, SpanState::ExecStart, Some(worker), None);
                }
                let exec = catch_unwind(AssertUnwindSafe(|| exp_fn(&ctx)));
                if let Some(t) = &tracer {
                    t.record(spec.index, attempt, SpanState::ExecEnd, Some(worker), None);
                }
                match exec {
                    Ok(Ok(v)) => break Some(v),
                    Ok(Err(e)) => {
                        last_failure = Some(TaskFailure {
                            kind: FailureKind::Error,
                            message: e.to_string(),
                            params: spec.param_strings(),
                            attempts: attempt,
                        });
                    }
                    Err(payload) => {
                        last_failure = Some(TaskFailure {
                            kind: FailureKind::Panic,
                            message: panic_message(payload.as_ref()),
                            params: spec.param_strings(),
                            attempts: attempt,
                        });
                    }
                }
                if let (Some(j), Some(f)) = (&journal, &last_failure) {
                    j.record(&Event::TaskFailed {
                        id: id.clone(),
                        attempt,
                        message: f.message.clone(),
                    });
                }
                if !retry.should_retry(attempt) {
                    break None;
                }
            };

            let duration = sw.elapsed_secs();
            metrics.exec_time.record(sw.elapsed());

            let outcome = match value {
                Some(v) => {
                    metrics.tasks_succeeded.inc();
                    if let Some(j) = &journal {
                        j.record(&Event::TaskSucceeded {
                            id: id.clone(),
                            attempt,
                            duration_secs: duration,
                        });
                    }
                    if let Some(cache) = &cache {
                        let _ = cache.put(&id, spec, &v);
                    }
                    if let Some(ck) = &checkpoint {
                        let _ = ck.record(&id, Some(&v), None, duration, attempt);
                        ck.clear_progress(&id);
                    }
                    TaskOutcome {
                        spec: spec.clone(),
                        id,
                        status: TaskStatus::Success,
                        value: Some(v),
                        failure: None,
                        duration_secs: duration,
                        from_cache: false,
                        attempts: attempt,
                    }
                }
                None => {
                    metrics.tasks_failed.inc();
                    let failure = last_failure.expect("failure recorded on miss");
                    if let Some(ck) = &checkpoint {
                        let _ = ck.record(
                            &id,
                            None,
                            Some(&failure.message),
                            duration,
                            attempt,
                        );
                    }
                    if let Some(n) = &notifier {
                        n.notify(&Notification::TaskFailed { failure: failure.clone() });
                    }
                    TaskOutcome {
                        spec: spec.clone(),
                        id,
                        status: TaskStatus::Failed,
                        value: None,
                        failure: Some(failure),
                        duration_secs: duration,
                        from_cache: false,
                        attempts: attempt,
                    }
                }
            };
            // Release *after* the cache/checkpoint writes above: parked
            // concurrent runs re-probe the cache on wake-up and must see
            // the value (owner-checked; no-op without a gate claim).
            if let Some(gate) = &inflight {
                gate.release(&outcome.id.0, &run_label);
            }
            if let Some(t) = &tracer {
                // Recorded lands after cache/checkpoint persistence, so
                // the span covers the full record pipeline.
                t.record(spec.index, attempt, SpanState::Recorded, None, None);
            }
            outcome
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};
    use crate::coordinator::notify::MemoryNotificationProvider;
    use crate::util::fs::TempDir;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn small_matrix() -> ConfigMatrix {
        ConfigMatrix::builder()
            .param("a", vec![pv_int(1), pv_int(2), pv_int(3)])
            .param("b", vec![pv_str("x"), pv_str("y")])
            .setting("bias", Json::int(100))
            .build()
            .unwrap()
    }

    #[test]
    fn runs_full_product() {
        let results = Memento::new(|ctx| {
            let a = ctx.param_i64("a")?;
            let bias = ctx.setting_i64("bias", 0);
            Ok(Json::int(a + bias))
        })
        .workers(4)
        .run(&small_matrix())
        .unwrap();
        assert_eq!(results.len(), 6);
        assert_eq!(results.n_failed(), 0);
        let hit = results.find(&[("a", pv_int(2)), ("b", pv_str("x"))]).unwrap();
        assert_eq!(hit.value.as_ref().unwrap().as_i64(), Some(102));
    }

    #[test]
    fn failures_are_isolated_and_reported() {
        let notifier = Arc::new(MemoryNotificationProvider::new());
        let results = Memento::new(|ctx| {
            if ctx.param_i64("a")? == 2 {
                Err(MementoError::experiment("a=2 always fails"))
            } else {
                Ok(Json::int(0))
            }
        })
        .workers(2)
        .with_shared_notifier(Arc::clone(&notifier) as Arc<dyn NotificationProvider>)
        .run(&small_matrix())
        .unwrap();
        assert_eq!(results.len(), 6);
        assert_eq!(results.n_failed(), 2); // a=2 × {x,y}
        let failures: Vec<_> = results.failures().collect();
        assert!(failures
            .iter()
            .all(|f| f.failure.as_ref().unwrap().message.contains("a=2")));
        // start + 2 task-failed + finished
        assert_eq!(notifier.count(), 4);
    }

    #[test]
    fn panics_become_failures() {
        let results = Memento::new(|ctx| {
            if ctx.param_str("b")? == "y" {
                panic!("kaboom on y");
            }
            Ok(Json::int(1))
        })
        .workers(3)
        .run(&small_matrix())
        .unwrap();
        assert_eq!(results.n_failed(), 3);
        let f = results.failures().next().unwrap().failure.clone().unwrap();
        assert_eq!(f.kind, FailureKind::Panic);
        assert!(f.message.contains("kaboom"));
    }

    #[test]
    fn cache_prevents_reexecution() {
        let td = TempDir::new("memento-cache").unwrap();
        let executions = Arc::new(AtomicUsize::new(0));
        let make = |ex: Arc<AtomicUsize>| {
            Memento::new(move |ctx| {
                ex.fetch_add(1, Ordering::SeqCst);
                Ok(Json::int(ctx.param_i64("a")?))
            })
            .workers(2)
            .with_cache_dir(td.join("cache"))
        };
        let m1 = make(Arc::clone(&executions));
        let r1 = m1.run(&small_matrix()).unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 6);
        assert_eq!(r1.n_cached(), 0);

        let m2 = make(Arc::clone(&executions));
        let r2 = m2.run(&small_matrix()).unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 6, "no re-execution");
        assert_eq!(r2.n_cached(), 6);
        assert_eq!(r2.len(), 6);
        // values identical
        for o in r2.iter() {
            let orig = r1.find(&[
                ("a", o.spec.get("a").unwrap().clone()),
                ("b", o.spec.get("b").unwrap().clone()),
            ]);
            assert_eq!(orig.unwrap().value, o.value);
        }
    }

    #[test]
    fn shared_cache_handle_serves_second_run_from_memory() {
        // With a shared ResultCache handle, a re-run must restore every
        // task from the memory tier — zero disk reads on the warm path.
        let td = TempDir::new("memento-two-tier").unwrap();
        let cache = Arc::new(ResultCache::open(td.join("cache")).unwrap());
        let run = |cache: Arc<ResultCache>| {
            Memento::new(|ctx| Ok(Json::int(ctx.param_i64("a")?)))
                .workers(2)
                .with_cache(cache)
                .run(&small_matrix())
                .unwrap()
        };
        let r1 = run(Arc::clone(&cache));
        assert_eq!(r1.n_cached(), 0);
        let (mem_before, _) = cache.stats().tier_snapshot();
        let r2 = run(Arc::clone(&cache));
        assert_eq!(r2.n_cached(), 6);
        let (mem_after, disk_after) = cache.stats().tier_snapshot();
        assert_eq!(mem_after - mem_before, 6, "all warm hits from memory");
        assert_eq!(disk_after, 0, "no disk reads at any point");
        assert_eq!(cache.len(), 6);
    }

    #[test]
    fn dispatch_metrics_populated_by_run() {
        let m = Memento::new(|_| Ok(Json::Null)).workers(3);
        let metrics = m.metrics();
        m.run(&small_matrix()).unwrap();
        assert!(metrics.dispatch_chunks.get() > 0, "chunked dispatch used");
        assert_eq!(metrics.tasks_skipped.get(), 0);
        assert!(metrics.dispatch_overhead.count() > 0);
    }

    #[test]
    fn version_bump_invalidates_cache() {
        let td = TempDir::new("memento-version").unwrap();
        let executions = Arc::new(AtomicUsize::new(0));
        for (version, expected_total) in [("v1", 6usize), ("v1", 6), ("v2", 12)] {
            let ex = Arc::clone(&executions);
            let m = Memento::new(move |_| {
                ex.fetch_add(1, Ordering::SeqCst);
                Ok(Json::int(0))
            })
            .version(version)
            .with_cache_dir(td.join("cache"));
            m.run(&small_matrix()).unwrap();
            assert_eq!(executions.load(Ordering::SeqCst), expected_total);
        }
    }

    #[test]
    fn retry_policy_retries_then_succeeds() {
        let attempts_seen = Arc::new(AtomicUsize::new(0));
        let a2 = Arc::clone(&attempts_seen);
        let matrix = ConfigMatrix::builder()
            .param("only", vec![pv_int(1)])
            .build()
            .unwrap();
        let results = Memento::new(move |ctx| {
            a2.fetch_add(1, Ordering::SeqCst);
            if ctx.attempt < 3 {
                Err(MementoError::experiment("transient"))
            } else {
                Ok(Json::int(7))
            }
        })
        .with_retry(RetryPolicy::fixed(3, Duration::ZERO))
        .run(&matrix)
        .unwrap();
        assert_eq!(results.n_failed(), 0);
        assert_eq!(attempts_seen.load(Ordering::SeqCst), 3);
        assert_eq!(results.outcomes()[0].attempts, 3);
    }

    #[test]
    fn retry_exhaustion_reports_attempts() {
        let matrix = ConfigMatrix::builder()
            .param("only", vec![pv_int(1)])
            .build()
            .unwrap();
        let results = Memento::new(|_| -> Result<Json, MementoError> {
            Err(MementoError::experiment("always"))
        })
        .with_retry(RetryPolicy::fixed(3, Duration::ZERO))
        .run(&matrix)
        .unwrap();
        assert_eq!(results.n_failed(), 1);
        assert_eq!(results.outcomes()[0].attempts, 3);
    }

    #[test]
    fn fail_fast_aborts() {
        let err = Memento::new(|_| -> Result<Json, MementoError> {
            Err(MementoError::experiment("nope"))
        })
        .workers(1)
        .fail_fast(true)
        .run(&small_matrix())
        .unwrap_err();
        assert!(matches!(err, MementoError::Aborted(_)), "{err}");
    }

    #[test]
    fn checkpoint_and_resume_skip_done_tasks() {
        let td = TempDir::new("memento-resume").unwrap();
        let run_dir = td.join("run");
        let executions = Arc::new(AtomicUsize::new(0));

        // First run: a=3 fails.
        let ex = Arc::clone(&executions);
        let m = Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            if ctx.param_i64("a")? == 3 {
                Err(MementoError::experiment("flaky"))
            } else {
                Ok(Json::int(ctx.param_i64("a")?))
            }
        })
        .workers(2)
        .with_checkpoint_dir(&run_dir);
        let r1 = m.run(&small_matrix()).unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 6);
        assert_eq!(r1.n_failed(), 2);

        // Resume: only the 2 failed tasks re-run (and now succeed).
        let ex = Arc::clone(&executions);
        let m = Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            Ok(Json::int(ctx.param_i64("a")?))
        })
        .workers(2)
        .with_checkpoint_dir(&run_dir);
        let r2 = m.resume(&small_matrix()).unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 8, "only failed re-ran");
        assert_eq!(r2.len(), 6);
        assert_eq!(r2.n_failed(), 0);
        assert_eq!(r2.n_cached(), 4);
    }

    #[test]
    fn resume_without_checkpoint_dir_errors() {
        let err = Memento::new(|_| Ok(Json::Null))
            .resume(&small_matrix())
            .unwrap_err();
        assert!(err.to_string().contains("with_checkpoint_dir"), "{err}");
    }

    #[test]
    fn resume_rejects_matrix_change() {
        let td = TempDir::new("memento-fpmismatch").unwrap();
        let run_dir = td.join("run");
        Memento::new(|_| Ok(Json::Null))
            .with_checkpoint_dir(&run_dir)
            .run(&small_matrix())
            .unwrap();
        let other = ConfigMatrix::builder()
            .param("a", vec![pv_int(9)])
            .build()
            .unwrap();
        let err = Memento::new(|_| Ok(Json::Null))
            .with_checkpoint_dir(&run_dir)
            .resume(&other)
            .unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
    }

    #[test]
    fn task_seeds_are_deterministic_across_runs() {
        let seeds = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
        let run = |seeds: Arc<std::sync::Mutex<Vec<u64>>>| {
            Memento::new(move |ctx| {
                seeds.lock().unwrap().push(ctx.seed);
                Ok(Json::Null)
            })
            .seed(42)
            .workers(3)
            .run(&small_matrix())
            .unwrap();
        };
        run(Arc::clone(&seeds));
        let mut first: Vec<u64> = seeds.lock().unwrap().drain(..).collect();
        first.sort_unstable();
        run(Arc::clone(&seeds));
        let mut second: Vec<u64> = seeds.lock().unwrap().drain(..).collect();
        second.sort_unstable();
        assert_eq!(first, second);
        // distinct per task
        let mut dedup = first.clone();
        dedup.dedup();
        assert_eq!(dedup.len(), 6);
    }

    #[test]
    fn metrics_are_populated() {
        let m = Memento::new(|_| Ok(Json::Null)).workers(2);
        let metrics = m.metrics();
        m.run(&small_matrix()).unwrap();
        assert_eq!(metrics.tasks_total.get(), 6);
        assert_eq!(metrics.tasks_succeeded.get(), 6);
        assert!(metrics.exec_time.count() >= 6);
    }

    #[test]
    fn journal_records_full_lifecycle() {
        let td = TempDir::new("memento-journal").unwrap();
        let jpath = td.join("run/journal.jsonl");
        let cache_dir = td.join("cache");
        let matrix = ConfigMatrix::builder()
            .param("i", vec![pv_int(0), pv_int(1)])
            .build()
            .unwrap();
        // First run: i=1 fails once then succeeds (retry).
        let r = Memento::new(|ctx| {
            if ctx.param_i64("i")? == 1 && ctx.attempt == 1 {
                Err(MementoError::experiment("flaky"))
            } else {
                Ok(Json::Null)
            }
        })
        .with_retry(RetryPolicy::fixed(2, Duration::ZERO))
        .with_cache_dir(&cache_dir)
        .with_journal(&jpath)
        .run(&matrix)
        .unwrap();
        assert_eq!(r.n_failed(), 0);
        // Second run: both restored from cache.
        Memento::new(|_| Ok(Json::Null))
            .with_cache_dir(&cache_dir)
            .with_journal(&jpath)
            .run(&matrix)
            .unwrap();

        let s = crate::coordinator::journal::Journal::summarize(&jpath).unwrap();
        assert_eq!(s.started, 3, "2 first attempts + 1 retry");
        assert_eq!(s.succeeded, 2);
        assert_eq!(s.failed_attempts, 1);
        assert_eq!(s.restored, 2);
    }

    #[test]
    fn in_task_progress_survives_retries() {
        let td = TempDir::new("memento-progress").unwrap();
        let matrix = ConfigMatrix::builder()
            .param("only", vec![pv_int(1)])
            .build()
            .unwrap();
        let observed = Arc::new(std::sync::Mutex::new(Vec::<Option<i64>>::new()));
        let obs = Arc::clone(&observed);
        let results = Memento::new(move |ctx| {
            let restored = ctx.restored().and_then(|j| j.as_i64());
            obs.lock().unwrap().push(restored);
            ctx.save_progress(Json::int(restored.unwrap_or(0) + 1));
            if ctx.attempt < 3 {
                Err(MementoError::experiment("again"))
            } else {
                Ok(Json::int(99))
            }
        })
        .with_retry(RetryPolicy::fixed(3, Duration::ZERO))
        .with_checkpoint_dir(td.join("run"))
        .run(&matrix)
        .unwrap();
        assert_eq!(results.n_failed(), 0);
        // attempt1 restored None, attempt2 saw 1, attempt3 saw 2
        assert_eq!(*observed.lock().unwrap(), vec![None, Some(1), Some(2)]);
    }

    #[test]
    fn shared_store_restores_second_run_without_execution() {
        // Acceptance criterion for the cross-run database: two consecutive
        // runs of the same grid against one store — the second executes
        // zero tasks, restoring everything from the store's records.
        let td = TempDir::new("memento-store").unwrap();
        let executions = Arc::new(AtomicUsize::new(0));
        let run = |ex: Arc<AtomicUsize>| {
            Memento::new(move |ctx| {
                ex.fetch_add(1, Ordering::SeqCst);
                Ok(Json::int(ctx.param_i64("a")?))
            })
            .workers(2)
            .store_dir(td.join("store"))
            .run(&small_matrix())
            .unwrap()
        };
        let r1 = run(Arc::clone(&executions));
        assert_eq!(executions.load(Ordering::SeqCst), 6);
        assert_eq!(r1.n_cached(), 0);
        let r2 = run(Arc::clone(&executions));
        assert_eq!(
            executions.load(Ordering::SeqCst),
            6,
            "second run must execute zero tasks"
        );
        assert_eq!(r2.n_cached(), 6);
        assert_eq!(r2.n_failed(), 0);
        // The store holds one record per task and registered both runs.
        let store = crate::store::ResultStore::open(td.join("store")).unwrap();
        assert_eq!(store.stats().live_records, 6);
        assert_eq!(store.runs().len(), 2);
    }

    #[test]
    fn store_backed_checkpoint_resumes_failed_tasks_only() {
        let td = TempDir::new("memento-store-ck").unwrap();
        let run_dir = td.join("run");
        let executions = Arc::new(AtomicUsize::new(0));

        let ex = Arc::clone(&executions);
        let m = Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            if ctx.param_i64("a")? == 3 {
                Err(MementoError::experiment("flaky"))
            } else {
                Ok(Json::int(ctx.param_i64("a")?))
            }
        })
        .workers(2)
        .store_dir(td.join("store"))
        .with_checkpoint_dir(&run_dir);
        let r1 = m.run(&small_matrix()).unwrap();
        assert_eq!(r1.n_failed(), 2);
        assert!(
            !run_dir.join("manifest.json").exists(),
            "store-backed checkpoint writes no manifest file"
        );

        // Resume through a fresh handle over the same store: only the two
        // failed tasks re-run.
        let ex = Arc::clone(&executions);
        let m = Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            Ok(Json::int(ctx.param_i64("a")?))
        })
        .workers(2)
        .store_dir(td.join("store"))
        .with_checkpoint_dir(&run_dir);
        let r2 = m.resume(&small_matrix()).unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 8, "only failed re-ran");
        assert_eq!(r2.len(), 6);
        assert_eq!(r2.n_failed(), 0);
        assert_eq!(r2.n_cached(), 4);
    }

    #[test]
    fn legacy_manifest_wins_over_store_on_resume() {
        // A run dir checkpointed before the store existed must keep
        // resuming from its manifest.json even when a store is configured.
        let td = TempDir::new("memento-legacy-ck").unwrap();
        let run_dir = td.join("run");
        Memento::new(|ctx| Ok(Json::int(ctx.param_i64("a")?)))
            .with_checkpoint_dir(&run_dir)
            .run(&small_matrix())
            .unwrap();
        assert!(run_dir.join("manifest.json").exists());

        let executions = Arc::new(AtomicUsize::new(0));
        let ex = Arc::clone(&executions);
        let r = Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            Ok(Json::int(ctx.param_i64("a")?))
        })
        .store_dir(td.join("store"))
        .with_checkpoint_dir(&run_dir)
        .resume(&small_matrix())
        .unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 0, "all restored from manifest");
        assert_eq!(r.n_cached(), 6);
    }

    // ---- experiment registry ----------------------------------------------

    fn two_exp_registry() -> Registry {
        Registry::new()
            .register("ten", "v1", "x*10", |ctx| Ok(Json::int(ctx.param_i64("x")? * 10)))
            .register("neg", "v1", "-x", |ctx| Ok(Json::int(-ctx.param_i64("x")?)))
    }

    #[test]
    fn registry_mixes_experiments_via_row_param() {
        let matrix = ConfigMatrix::builder()
            .param("exp", vec![pv_str("ten"), pv_str("neg")])
            .param("x", vec![pv_int(1), pv_int(2)])
            .build()
            .unwrap();
        let results = Memento::with_registry(two_exp_registry())
            .workers(2)
            .run(&matrix)
            .unwrap();
        assert_eq!(results.len(), 4);
        assert_eq!(results.n_failed(), 0);
        let ten2 = results
            .find(&[("exp", pv_str("ten")), ("x", pv_int(2))])
            .unwrap();
        assert_eq!(ten2.value.as_ref().unwrap().as_i64(), Some(20));
        let neg2 = results
            .find(&[("exp", pv_str("neg")), ("x", pv_int(2))])
            .unwrap();
        assert_eq!(neg2.value.as_ref().unwrap().as_i64(), Some(-2));
        // Every outcome's spec carries the reference it resolved.
        for o in results.iter() {
            let named = o.spec.exp.as_ref().expect("row-named specs carry ExpRef");
            assert_eq!(
                Some(named.name.as_str()),
                o.spec.get("exp").and_then(|v| v.as_str())
            );
            assert_eq!(named.version, "v1");
        }
    }

    #[test]
    fn run_level_exp_selects_entry_for_all_tasks() {
        let matrix = ConfigMatrix::builder()
            .param("x", vec![pv_int(1), pv_int(3)])
            .build()
            .unwrap();
        let results = Memento::with_registry(two_exp_registry())
            .exp("neg")
            .run(&matrix)
            .unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results.n_failed(), 0);
        let hit = results.find(&[("x", pv_int(3))]).unwrap();
        assert_eq!(hit.value.as_ref().unwrap().as_i64(), Some(-3));
    }

    #[test]
    fn run_level_unknown_exp_is_config_error() {
        let err = Memento::with_registry(two_exp_registry())
            .exp("mystery")
            .run(&small_matrix())
            .unwrap_err();
        assert!(err.to_string().contains("unregistered experiment"), "{err}");
        assert!(err.to_string().contains("neg, ten"), "{err}");
    }

    #[test]
    fn unknown_row_exp_fails_typed_without_retry() {
        let matrix = ConfigMatrix::builder()
            .param("exp", vec![pv_str("ten"), pv_str("mystery")])
            .param("x", vec![pv_int(1)])
            .build()
            .unwrap();
        let results = Memento::with_registry(two_exp_registry())
            .with_retry(RetryPolicy::fixed(3, Duration::ZERO))
            .run(&matrix)
            .unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results.n_failed(), 1);
        let f = results.failures().next().unwrap().failure.clone().unwrap();
        assert_eq!(f.kind, FailureKind::UnknownExperiment);
        assert_eq!(f.attempts, 0, "no retry loop for an unresolvable task");
        assert!(f.message.contains("unknown experiment 'mystery'"), "{}", f.message);
    }

    #[test]
    fn registry_fallback_restores_pre_registry_cache() {
        // The fingerprint-compatibility rule, enforced: a cache written by
        // the pre-registry API (`Memento::new`) restores with zero
        // executions under a registry run, because unnamed tasks hash
        // exactly as they always did.
        let td = TempDir::new("memento-reg-compat").unwrap();
        let executions = Arc::new(AtomicUsize::new(0));
        let ex = Arc::clone(&executions);
        Memento::new(move |ctx| {
            ex.fetch_add(1, Ordering::SeqCst);
            Ok(Json::int(ctx.param_i64("a")?))
        })
        .with_cache_dir(td.join("cache"))
        .run(&small_matrix())
        .unwrap();
        assert_eq!(executions.load(Ordering::SeqCst), 6);

        let ex = Arc::clone(&executions);
        let registry = Registry::new()
            .register("other", "v1", "unused by this matrix", |_| Ok(Json::Null))
            .register_default(move |ctx| {
                ex.fetch_add(1, Ordering::SeqCst);
                Ok(Json::int(ctx.param_i64("a")?))
            });
        let r2 = Memento::with_registry(registry)
            .with_cache_dir(td.join("cache"))
            .run(&small_matrix())
            .unwrap();
        assert_eq!(
            executions.load(Ordering::SeqCst),
            6,
            "all restored, zero executions"
        );
        assert_eq!(r2.n_cached(), 6);
    }

    #[test]
    fn entry_version_bump_invalidates_only_that_experiment() {
        let td = TempDir::new("memento-entry-version").unwrap();
        let matrix = ConfigMatrix::builder()
            .param("exp", vec![pv_str("ten"), pv_str("neg")])
            .param("x", vec![pv_int(1), pv_int(2)])
            .build()
            .unwrap();
        let executions = Arc::new(AtomicUsize::new(0));
        let run = |neg_version: &str| {
            let e1 = Arc::clone(&executions);
            let e2 = Arc::clone(&executions);
            Memento::with_registry(
                Registry::new()
                    .register("ten", "v1", "x*10", move |ctx| {
                        e1.fetch_add(1, Ordering::SeqCst);
                        Ok(Json::int(ctx.param_i64("x")? * 10))
                    })
                    .register("neg", neg_version, "-x", move |ctx| {
                        e2.fetch_add(1, Ordering::SeqCst);
                        Ok(Json::int(-ctx.param_i64("x")?))
                    }),
            )
            .with_cache_dir(td.join("cache"))
            .run(&matrix)
            .unwrap()
        };
        let r1 = run("v1");
        assert_eq!(executions.load(Ordering::SeqCst), 4);
        assert_eq!(r1.n_cached(), 0);
        // Bumping only `neg`'s version re-executes only its two tasks.
        let r2 = run("v2");
        assert_eq!(executions.load(Ordering::SeqCst), 6, "ten stayed cached");
        assert_eq!(r2.n_cached(), 2);
        assert_eq!(r2.n_failed(), 0);
    }
}
