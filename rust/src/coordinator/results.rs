//! Run results: per-task outcomes and the queryable [`ResultSet`].
//!
//! After a run, the user wants (a) the value each experiment produced,
//! (b) which combinations failed and why, and (c) pivoted summary tables
//! (the §3 accuracy grid). `ResultSet` provides lookup by parameter
//! assignment, filtering, group-by aggregation, and an ASCII table renderer.

use crate::config::value::ParamValue;
use crate::coordinator::error::TaskFailure;
use crate::coordinator::task::{TaskId, TaskSpec};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Terminal state of one task.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskStatus {
    /// Experiment function returned a value.
    Success,
    /// All attempts failed.
    Failed,
}

/// Full record for one executed (or cache-restored) task.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    /// The task's parameter assignment.
    pub spec: TaskSpec,
    /// The task's content-hash identity.
    pub id: TaskId,
    /// Terminal status.
    pub status: TaskStatus,
    /// Present iff `status == Success`.
    pub value: Option<Json>,
    /// Present iff `status == Failed`.
    pub failure: Option<TaskFailure>,
    /// Wall-clock seconds spent executing (0.0 for pure cache hits).
    pub duration_secs: f64,
    /// True when the value came from the result cache.
    pub from_cache: bool,
    /// Attempts actually made (0 for cache hits).
    pub attempts: u32,
}

impl TaskOutcome {
    /// True for successful outcomes (restores included).
    pub fn succeeded(&self) -> bool {
        self.status == TaskStatus::Success
    }

    /// Extracts a named f64 field from an object-valued result — the common
    /// "accuracy" / "f1" lookup when aggregating.
    pub fn metric(&self, field: &str) -> Option<f64> {
        self.value.as_ref()?.get(field)?.as_f64()
    }

    /// Serializes one outcome — the row shape used both by
    /// [`ResultSet::to_json`] and the CLI's `--output ndjson` event stream.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("id", Json::str(self.id.0.clone())),
            ("params", self.spec.to_json()),
            (
                "status",
                Json::str(if self.succeeded() { "success" } else { "failed" }),
            ),
            ("duration_secs", Json::Num(self.duration_secs)),
            ("from_cache", Json::Bool(self.from_cache)),
            ("attempts", Json::int(self.attempts as i64)),
        ];
        if let Some(v) = &self.value {
            fields.push(("value", v.clone()));
        }
        if let Some(f) = &self.failure {
            fields.push(("failure", Json::str(f.summary())));
        }
        Json::obj(fields)
    }
}

/// The collection of outcomes for one run.
#[derive(Debug, Clone, Default)]
pub struct ResultSet {
    outcomes: Vec<TaskOutcome>,
}

impl ResultSet {
    /// Collects outcomes into a deterministic result set.
    pub fn new(mut outcomes: Vec<TaskOutcome>) -> Self {
        // Stable order: by expansion index, so reports are deterministic
        // regardless of worker interleaving.
        outcomes.sort_by_key(|o| o.spec.index);
        ResultSet { outcomes }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.outcomes.len()
    }

    /// True when the set holds no outcomes.
    pub fn is_empty(&self) -> bool {
        self.outcomes.is_empty()
    }

    /// Iterates every outcome in expansion order.
    pub fn iter(&self) -> impl Iterator<Item = &TaskOutcome> {
        self.outcomes.iter()
    }

    /// The outcomes as a slice, in expansion order.
    pub fn outcomes(&self) -> &[TaskOutcome] {
        &self.outcomes
    }

    /// Iterates the successful outcomes.
    pub fn successes(&self) -> impl Iterator<Item = &TaskOutcome> {
        self.outcomes.iter().filter(|o| o.succeeded())
    }

    /// Iterates the failed outcomes.
    pub fn failures(&self) -> impl Iterator<Item = &TaskOutcome> {
        self.outcomes.iter().filter(|o| !o.succeeded())
    }

    /// Number of failed outcomes.
    pub fn n_failed(&self) -> usize {
        self.failures().count()
    }

    /// Number of outcomes restored from cache/checkpoint.
    pub fn n_cached(&self) -> usize {
        self.outcomes.iter().filter(|o| o.from_cache).count()
    }

    /// Finds the outcome whose spec assigns exactly the given pairs (a
    /// subset match: all given pairs must hold).
    pub fn find(&self, pairs: &[(&str, ParamValue)]) -> Option<&TaskOutcome> {
        self.outcomes.iter().find(|o| {
            pairs
                .iter()
                .all(|(k, v)| o.spec.get(k).map(|h| h == v).unwrap_or(false))
        })
    }

    /// All outcomes matching a partial assignment.
    pub fn filter(&self, pairs: &[(&str, ParamValue)]) -> Vec<&TaskOutcome> {
        self.outcomes
            .iter()
            .filter(|o| {
                pairs
                    .iter()
                    .all(|(k, v)| o.spec.get(k).map(|h| h == v).unwrap_or(false))
            })
            .collect()
    }

    /// Mean of `metric` over successful outcomes grouped by `param`'s value.
    pub fn mean_by(&self, param: &str, metric: &str) -> Vec<(ParamValue, f64, usize)> {
        let mut groups: Vec<(ParamValue, Vec<f64>)> = Vec::new();
        for o in self.successes() {
            let (Some(v), Some(m)) = (o.spec.get(param), o.metric(metric)) else {
                continue;
            };
            match groups.iter_mut().find(|(gv, _)| gv == v) {
                Some((_, xs)) => xs.push(m),
                None => groups.push((v.clone(), vec![m])),
            }
        }
        groups
            .into_iter()
            .map(|(v, xs)| {
                let n = xs.len();
                (v, xs.iter().sum::<f64>() / n as f64, n)
            })
            .collect()
    }

    /// Pivot table: rows = values of `row_param`, cols = values of
    /// `col_param`, cells = mean of `metric` over matching successes.
    pub fn pivot(
        &self,
        row_param: &str,
        col_param: &str,
        metric: &str,
    ) -> PivotTable {
        let mut rows: Vec<ParamValue> = Vec::new();
        let mut cols: Vec<ParamValue> = Vec::new();
        for o in self.outcomes.iter() {
            if let Some(r) = o.spec.get(row_param) {
                if !rows.contains(r) {
                    rows.push(r.clone());
                }
            }
            if let Some(c) = o.spec.get(col_param) {
                if !cols.contains(c) {
                    cols.push(c.clone());
                }
            }
        }
        let mut cells = vec![vec![None; cols.len()]; rows.len()];
        for (ri, r) in rows.iter().enumerate() {
            for (ci, c) in cols.iter().enumerate() {
                let xs: Vec<f64> = self
                    .successes()
                    .filter(|o| o.spec.get(row_param) == Some(r) && o.spec.get(col_param) == Some(c))
                    .filter_map(|o| o.metric(metric))
                    .collect();
                if !xs.is_empty() {
                    cells[ri][ci] = Some(xs.iter().sum::<f64>() / xs.len() as f64);
                }
            }
        }
        PivotTable {
            row_param: row_param.to_string(),
            col_param: col_param.to_string(),
            metric: metric.to_string(),
            rows,
            cols,
            cells,
        }
    }

    /// One-paragraph run summary (used by notifications and the CLI).
    pub fn summary(&self) -> String {
        let total = self.len();
        let failed = self.n_failed();
        let cached = self.n_cached();
        let exec_time: f64 = self.outcomes.iter().map(|o| o.duration_secs).sum();
        format!(
            "{total} task(s): {} succeeded, {failed} failed, {cached} from cache; \
             cumulative execution {}",
            total - failed,
            crate::util::time::fmt_secs(exec_time),
        )
    }

    /// Serializes all outcomes for persistence (`memento report`).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.outcomes.iter().map(TaskOutcome::to_json).collect())
    }
}

/// A rendered-on-demand pivot table (the §3 accuracy grid).
#[derive(Debug, Clone)]
pub struct PivotTable {
    /// Parameter whose values label the rows.
    pub row_param: String,
    /// Parameter whose values label the columns.
    pub col_param: String,
    /// Metric field averaged into each cell.
    pub metric: String,
    /// Row labels, in first-seen order.
    pub rows: Vec<ParamValue>,
    /// Column labels, in first-seen order.
    pub cols: Vec<ParamValue>,
    /// Cell means (`None` = no outcome for that row/column pair).
    pub cells: Vec<Vec<Option<f64>>>,
}

impl PivotTable {
    /// ASCII rendering with aligned columns; empty cells print `—`.
    pub fn render(&self) -> String {
        let mut header: Vec<String> = vec![format!("{}\\{}", self.row_param, self.col_param)];
        header.extend(self.cols.iter().map(|c| c.to_string()));
        let mut body: Vec<Vec<String>> = Vec::new();
        for (ri, r) in self.rows.iter().enumerate() {
            let mut row = vec![r.to_string()];
            for ci in 0..self.cols.len() {
                row.push(match self.cells[ri][ci] {
                    Some(x) => format!("{x:.4}"),
                    None => "—".to_string(),
                });
            }
            body.push(row);
        }
        let mut widths: Vec<usize> = header.iter().map(|h| h.chars().count()).collect();
        for row in &body {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let fmt_row = |row: &[String]| -> String {
            row.iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("{} (mean {})\n", fmt_row(&header), self.metric);
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &body {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// BTreeMap-based frequency count helper shared by reports.
pub fn count_by<'a>(
    outcomes: impl Iterator<Item = &'a TaskOutcome>,
    param: &str,
) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for o in outcomes {
        if let Some(v) = o.spec.get(param) {
            *m.entry(v.to_string()).or_insert(0) += 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};
    use crate::coordinator::error::FailureKind;

    fn outcome(ds: &str, model: &str, acc: f64, index: usize) -> TaskOutcome {
        let spec = TaskSpec {
            params: vec![
                ("dataset".into(), pv_str(ds)),
                ("model".into(), pv_str(model)),
            ],
            index,
            exp: None,
        };
        let id = spec.id("v1");
        TaskOutcome {
            spec,
            id,
            status: TaskStatus::Success,
            value: Some(Json::obj(vec![("accuracy", Json::Num(acc))])),
            failure: None,
            duration_secs: 0.1,
            from_cache: false,
            attempts: 1,
        }
    }

    fn failed_outcome(ds: &str, index: usize) -> TaskOutcome {
        let spec = TaskSpec {
            params: vec![
                ("dataset".into(), pv_str(ds)),
                ("model".into(), pv_str("SVC")),
            ],
            index,
            exp: None,
        };
        let id = spec.id("v1");
        TaskOutcome {
            spec: spec.clone(),
            id,
            status: TaskStatus::Failed,
            value: None,
            failure: Some(TaskFailure {
                kind: FailureKind::Error,
                message: "bad".into(),
                params: spec.param_strings(),
                attempts: 2,
            }),
            duration_secs: 0.05,
            from_cache: false,
            attempts: 2,
        }
    }

    fn sample() -> ResultSet {
        ResultSet::new(vec![
            outcome("wine", "SVC", 0.9, 2),
            outcome("wine", "RF", 0.8, 0),
            outcome("digits", "RF", 0.7, 1),
            failed_outcome("digits", 3),
        ])
    }

    #[test]
    fn ordering_by_index() {
        let rs = sample();
        let idx: Vec<usize> = rs.iter().map(|o| o.spec.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn counts() {
        let rs = sample();
        assert_eq!(rs.len(), 4);
        assert_eq!(rs.n_failed(), 1);
        assert_eq!(rs.successes().count(), 3);
        assert_eq!(rs.n_cached(), 0);
    }

    #[test]
    fn find_and_filter() {
        let rs = sample();
        let hit = rs
            .find(&[("dataset", pv_str("wine")), ("model", pv_str("SVC"))])
            .unwrap();
        assert!((hit.metric("accuracy").unwrap() - 0.9).abs() < 1e-12);
        assert_eq!(rs.filter(&[("dataset", pv_str("wine"))]).len(), 2);
        assert!(rs.find(&[("dataset", pv_str("nope"))]).is_none());
        assert!(rs.find(&[("missing_param", pv_int(1))]).is_none());
    }

    #[test]
    fn mean_by_groups_and_averages() {
        let rs = sample();
        let means = rs.mean_by("dataset", "accuracy");
        let wine = means.iter().find(|(v, _, _)| v == &pv_str("wine")).unwrap();
        assert!((wine.1 - 0.85).abs() < 1e-12);
        assert_eq!(wine.2, 2);
        // failed task contributes nothing
        let digits = means.iter().find(|(v, _, _)| v == &pv_str("digits")).unwrap();
        assert_eq!(digits.2, 1);
    }

    #[test]
    fn pivot_table_shape_and_render() {
        let rs = sample();
        let p = rs.pivot("dataset", "model", "accuracy");
        assert_eq!(p.rows.len(), 2);
        assert_eq!(p.cols.len(), 2);
        let rendered = p.render();
        assert!(rendered.contains("0.9000"), "{rendered}");
        assert!(rendered.contains("—"), "missing-cell marker: {rendered}");
        assert!(rendered.contains("dataset\\model"), "{rendered}");
    }

    #[test]
    fn summary_mentions_failures_and_cache() {
        let rs = sample();
        let s = rs.summary();
        assert!(s.contains("4 task(s)"), "{s}");
        assert!(s.contains("1 failed"), "{s}");
    }

    #[test]
    fn to_json_roundtrips_shape() {
        let rs = sample();
        let j = rs.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        let failed: Vec<_> = arr
            .iter()
            .filter(|o| o.get("status").unwrap().as_str() == Some("failed"))
            .collect();
        assert_eq!(failed.len(), 1);
        assert!(failed[0].get("failure").unwrap().as_str().unwrap().contains("bad"));
        // parse back
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 4);
    }

    #[test]
    fn count_by_works() {
        let rs = sample();
        let c = count_by(rs.iter(), "dataset");
        assert_eq!(c["wine"], 2);
        assert_eq!(c["digits"], 2);
    }
}
