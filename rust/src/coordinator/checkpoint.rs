//! Run checkpointing and resume.
//!
//! "MEMENTO saves the experiment output at regular intervals, allowing for
//! resumption without costly manual intervention" (§2). The checkpoint
//! store owns one run directory:
//!
//! ```text
//! <run_dir>/
//!   manifest.json       # matrix fingerprint, version, outcomes so far
//!   progress/<id>.json  # optional in-task partial progress
//! ```
//!
//! The manifest is rewritten atomically after every `flush_every` completed
//! tasks (and at the end of the run), so a crash loses at most the last
//! `flush_every - 1` completions — those tasks simply re-run on resume.
//! Resume refuses to run against a *different* matrix or experiment
//! version: that mismatch is exactly the "silently mixing results from two
//! experiment definitions" failure the fingerprint exists to prevent.
//!
//! Manifest and progress files are tagged binary ([`crate::util::codec`])
//! by default, compact JSON under
//! [`CheckpointStore::storage_format`]`(WireFormat::Json)`; readers
//! auto-detect per file, so run directories from older (JSON-only)
//! versions resume unchanged. The resume gate probes the fingerprint and
//! version with the lazy scanner ([`crate::util::scan`]) — a mismatched
//! manifest is refused without materializing its outcome map.
//!
//! # Store-backed mode
//!
//! [`CheckpointStore::create_in_store`] / `resume_in_store` keep the
//! manifest header and the completion entries as *records in a shared
//! segment-log store* ([`crate::store`]) keyed by a run label, instead of
//! rewriting `manifest.json`. Completions append one record each (no
//! rewrite amplification as the run grows), the flush interval becomes a
//! segment fsync cadence, and cross-run tooling (`memento query`,
//! `memento status --store`) sees every run in one place. In-task partial
//! progress stays as per-task scratch files under `<run_dir>/progress/`
//! either way — it is transient and per-run by nature. Legacy per-run
//! directories remain first-class: a `manifest.json` on disk wins over
//! the store when both could apply (see `Memento`), and `memento
//! migrate` folds old run dirs into store records.

use crate::coordinator::error::MementoError;
use crate::coordinator::task::TaskId;
use crate::store::ResultStore;
use crate::util::codec::{self, WireFormat};
use crate::util::fs::atomic_write;
use crate::util::json::Json;
use crate::util::scan::Scanner;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A completed task as stored in the manifest.
#[derive(Debug, Clone)]
pub struct CheckpointEntry {
    /// The task's content-hash identity.
    pub id: TaskId,
    /// `Some(value)` for successes, `None` for recorded failures.
    pub value: Option<Json>,
    /// The final failure message, for recorded failures.
    pub failed_message: Option<String>,
    /// Wall-clock execution time of the recorded outcome.
    pub duration_secs: f64,
    /// Attempts the recorded outcome took.
    pub attempts: u32,
}

impl CheckpointEntry {
    /// True when the entry records a successful outcome.
    pub fn succeeded(&self) -> bool {
        self.value.is_some()
    }
}

#[derive(Debug)]
struct Inner {
    entries: BTreeMap<TaskId, CheckpointEntry>,
    dirty_since_flush: usize,
}

/// Where manifest + completion entries persist.
enum Backing {
    /// `manifest.json` rewritten atomically in the run directory.
    Dir,
    /// Records in a shared segment-log store, keyed by the run label.
    Store(Arc<ResultStore>, String),
}

impl std::fmt::Debug for Backing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backing::Dir => write!(f, "Dir"),
            Backing::Store(_, run) => write!(f, "Store({run})"),
        }
    }
}

/// The checkpoint store for one run directory.
#[derive(Debug)]
pub struct CheckpointStore {
    run_dir: PathBuf,
    backing: Backing,
    matrix_fingerprint: String,
    version: String,
    /// Atomic because the streaming pipeline only learns the final total
    /// once the lazy expansion is exhausted ([`CheckpointStore::set_total`])
    /// — which can be after the first flushes have already happened.
    total_tasks: std::sync::atomic::AtomicUsize,
    flush_every: usize,
    /// Encoding for manifest/progress *writes*; reads always auto-detect,
    /// so a run directory written by an older (JSON-only) version resumes
    /// unchanged and converges to this format at the next flush.
    storage: WireFormat,
    /// Named experiment versions this run writes into the manifest header
    /// (see [`CheckpointStore::with_exps`]). Empty for single-experiment
    /// runs — the header then omits the field entirely, keeping
    /// pre-registry manifests byte-compatible.
    exps: BTreeMap<String, String>,
    /// Experiment versions read back from a resumed manifest header
    /// (empty when the manifest predates the registry or recorded none).
    stored_exps: BTreeMap<String, String>,
    inner: Mutex<Inner>,
}

impl CheckpointStore {
    /// Creates a fresh store (overwrites any existing manifest).
    pub fn create(
        run_dir: impl Into<PathBuf>,
        matrix_fingerprint: &str,
        version: &str,
        total_tasks: usize,
        flush_every: usize,
    ) -> Result<CheckpointStore, MementoError> {
        let run_dir = run_dir.into();
        std::fs::create_dir_all(run_dir.join("progress"))
            .map_err(|e| MementoError::storage(format!("create run dir: {e}")))?;
        let store = CheckpointStore {
            run_dir,
            backing: Backing::Dir,
            matrix_fingerprint: matrix_fingerprint.to_string(),
            version: version.to_string(),
            total_tasks: std::sync::atomic::AtomicUsize::new(total_tasks),
            flush_every: flush_every.max(1),
            storage: WireFormat::default(),
            exps: BTreeMap::new(),
            stored_exps: BTreeMap::new(),
            inner: Mutex::new(Inner { entries: BTreeMap::new(), dirty_since_flush: 0 }),
        };
        store.flush()?;
        Ok(store)
    }

    /// Creates a fresh store-backed checkpoint for the run labelled `run`:
    /// the manifest header and completions become records in `store`, and
    /// `run_dir` is used only for in-task progress scratch. Any previous
    /// checkpoint records under the same label are tombstoned first.
    pub fn create_in_store(
        store: Arc<ResultStore>,
        run: &str,
        run_dir: impl Into<PathBuf>,
        matrix_fingerprint: &str,
        version: &str,
        total_tasks: usize,
        flush_every: usize,
    ) -> Result<CheckpointStore, MementoError> {
        let run_dir = run_dir.into();
        std::fs::create_dir_all(run_dir.join("progress"))
            .map_err(|e| MementoError::storage(format!("create run dir: {e}")))?;
        store
            .clear_run(run)
            .map_err(|e| MementoError::storage(format!("clear run '{run}': {e}")))?;
        let ck = CheckpointStore {
            run_dir,
            backing: Backing::Store(store, run.to_string()),
            matrix_fingerprint: matrix_fingerprint.to_string(),
            version: version.to_string(),
            total_tasks: std::sync::atomic::AtomicUsize::new(total_tasks),
            flush_every: flush_every.max(1),
            storage: WireFormat::default(),
            exps: BTreeMap::new(),
            stored_exps: BTreeMap::new(),
            inner: Mutex::new(Inner { entries: BTreeMap::new(), dirty_since_flush: 0 }),
        };
        ck.flush()?;
        Ok(ck)
    }

    /// Resumes the run labelled `run` from its records in `store`,
    /// verifying the manifest header matches the matrix/version being
    /// resumed (same gate as [`CheckpointStore::resume`]).
    pub fn resume_in_store(
        store: Arc<ResultStore>,
        run: &str,
        run_dir: impl Into<PathBuf>,
        matrix_fingerprint: &str,
        version: &str,
        total_tasks: usize,
        flush_every: usize,
    ) -> Result<CheckpointStore, MementoError> {
        let run_dir = run_dir.into();
        let manifest = store
            .get_manifest(run)
            .map_err(|e| MementoError::storage(format!("read store manifest '{run}': {e}")))?
            .ok_or_else(|| {
                MementoError::storage(format!("no checkpoint for run '{run}' in store"))
            })?;
        let stored_fp = manifest
            .get("matrix_fingerprint")
            .and_then(|j| j.as_str())
            .unwrap_or("");
        if stored_fp != matrix_fingerprint {
            return Err(MementoError::CheckpointMismatch(format!(
                "store checkpoint '{run}' was written for matrix {stored_fp:.12}…, \
                 resuming with matrix {matrix_fingerprint:.12}…"
            )));
        }
        let stored_version = manifest.get("version").and_then(|j| j.as_str()).unwrap_or("");
        if stored_version != version {
            return Err(MementoError::CheckpointMismatch(format!(
                "store checkpoint '{run}' was written for experiment version \
                 '{stored_version}', current version is '{version}'"
            )));
        }
        let total_tasks = if total_tasks == 0 {
            manifest
                .get("total_tasks")
                .and_then(|j| j.as_i64())
                .map(|v| v.max(0) as usize)
                .unwrap_or(0)
        } else {
            total_tasks
        };
        let mut entries = BTreeMap::new();
        for doc in store
            .ck_entries(run)
            .map_err(|e| MementoError::storage(format!("read store entries '{run}': {e}")))?
        {
            let Some(id) = doc.get("id").and_then(|j| j.as_str()) else { continue };
            let id = TaskId(id.to_string());
            entries.insert(
                id.clone(),
                CheckpointEntry {
                    id,
                    value: doc.get("value").cloned(),
                    failed_message: doc
                        .get("failed")
                        .and_then(|j| j.as_str())
                        .map(|s| s.to_string()),
                    duration_secs: doc
                        .get("duration_secs")
                        .and_then(|j| j.as_f64())
                        .unwrap_or(0.0),
                    attempts: doc.get("attempts").and_then(|j| j.as_i64()).unwrap_or(1)
                        as u32,
                },
            );
        }
        std::fs::create_dir_all(run_dir.join("progress"))
            .map_err(|e| MementoError::storage(format!("create run dir: {e}")))?;
        Ok(CheckpointStore {
            run_dir,
            backing: Backing::Store(store, run.to_string()),
            matrix_fingerprint: matrix_fingerprint.to_string(),
            version: version.to_string(),
            total_tasks: std::sync::atomic::AtomicUsize::new(total_tasks),
            flush_every: flush_every.max(1),
            storage: WireFormat::default(),
            exps: BTreeMap::new(),
            stored_exps: Self::parse_exps(manifest.get("exps")),
            inner: Mutex::new(Inner { entries, dirty_since_flush: 0 }),
        })
    }

    /// True if `store` holds a checkpoint manifest for the run labelled
    /// `run`.
    pub fn exists_in_store(store: &ResultStore, run: &str) -> bool {
        matches!(store.get_manifest(run), Ok(Some(_)))
    }

    /// The run label, when store-backed.
    pub fn run_label(&self) -> Option<&str> {
        match &self.backing {
            Backing::Dir => None,
            Backing::Store(_, run) => Some(run),
        }
    }

    /// Chooses the encoding for subsequent manifest/progress writes:
    /// tagged binary (the default) or compact JSON for human-debuggable
    /// run directories. The manifest is rewritten whole on every flush,
    /// so the directory converges to the chosen format immediately.
    pub fn storage_format(mut self, format: WireFormat) -> Self {
        self.storage = format;
        self
    }

    /// Records the named experiment versions
    /// ([`crate::experiments::registry::Registry::versions`]) this run is
    /// using; the next flush writes them into the manifest header as an
    /// `exps` object. An empty map (single-experiment runs, and everything
    /// built by `Memento::new`) omits the field, so those manifests stay
    /// byte-identical to pre-registry ones.
    pub fn with_exps(mut self, exps: BTreeMap<String, String>) -> Self {
        self.exps = exps;
        self
    }

    /// The experiment versions a resumed manifest recorded (empty when
    /// the manifest predates the registry or recorded none).
    pub fn stored_exps(&self) -> &BTreeMap<String, String> {
        &self.stored_exps
    }

    /// The per-experiment version gate: refuses to resume when an
    /// experiment recorded in the manifest is also registered now *with a
    /// different version* — the per-entry analogue of the run-wide version
    /// check. Compared on the intersection only: experiments added since
    /// the checkpoint, dropped from the current registry, or runs whose
    /// manifest predates the registry (no `exps` field) pass freely.
    pub fn verify_exps(
        &self,
        current: &BTreeMap<String, String>,
    ) -> Result<(), MementoError> {
        for (name, stored) in &self.stored_exps {
            if let Some(now) = current.get(name) {
                if now != stored {
                    return Err(MementoError::CheckpointMismatch(format!(
                        "manifest recorded experiment '{name}' at version \
                         '{stored}', the registry now has it at '{now}'"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Reads an optional `exps` manifest field ({name: version}).
    fn parse_exps(j: Option<&Json>) -> BTreeMap<String, String> {
        j.and_then(|j| j.as_obj())
            .map(|pairs| {
                pairs
                    .iter()
                    .filter_map(|(n, v)| v.as_str().map(|s| (n.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// The `exps` header field value for the configured map.
    fn exps_json(&self) -> Json {
        Json::Obj(
            self.exps
                .iter()
                .map(|(n, v)| (n.clone(), Json::str(v.clone())))
                .collect(),
        )
    }

    /// Loads an existing manifest for resumption, verifying it matches the
    /// matrix/version being resumed.
    pub fn resume(
        run_dir: impl Into<PathBuf>,
        matrix_fingerprint: &str,
        version: &str,
        total_tasks: usize,
        flush_every: usize,
    ) -> Result<CheckpointStore, MementoError> {
        let run_dir: PathBuf = run_dir.into();
        let manifest_path = run_dir.join("manifest.json");
        let bytes = std::fs::read(&manifest_path).map_err(|e| {
            MementoError::storage(format!(
                "cannot read manifest '{}': {e}",
                manifest_path.display()
            ))
        })?;
        let corrupt = |e: crate::util::scan::ScanError| {
            MementoError::storage(format!("manifest corrupt: {e}"))
        };
        // Lazy probe first: the fingerprint/version gate needs three
        // scalar fields, so a mismatched (possibly huge) manifest is
        // refused without ever materializing its `completed` map.
        let scanner = Scanner::new(&bytes).map_err(corrupt)?;
        let [fp, ver, total] = scanner
            .fields(["matrix_fingerprint", "version", "total_tasks"])
            .map_err(corrupt)?;

        let stored_fp = fp.as_ref().and_then(|v| v.as_str()).unwrap_or("");
        if stored_fp != matrix_fingerprint {
            return Err(MementoError::CheckpointMismatch(format!(
                "manifest was written for matrix {stored_fp:.12}…, \
                 resuming with matrix {matrix_fingerprint:.12}…"
            )));
        }
        let stored_version = ver.as_ref().and_then(|v| v.as_str()).unwrap_or("");
        if stored_version != version {
            return Err(MementoError::CheckpointMismatch(format!(
                "manifest was written for experiment version '{stored_version}', \
                 current version is '{version}'"
            )));
        }

        // Streaming resumes pass total 0 (the lazy expansion hasn't been
        // counted yet); keep the manifest's stored total in that case so
        // a crash or cancel before `set_total` fires never clobbers a
        // previously-correct count with 0.
        let total_tasks = if total_tasks == 0 {
            total
                .as_ref()
                .and_then(|v| v.as_i64())
                .map(|v| v.max(0) as usize)
                .unwrap_or(0)
        } else {
            total_tasks
        };

        // Gate passed: now materialize the whole document to rebuild the
        // completed-entry map (either encoding; auto-detected).
        let doc = codec::read_document(&bytes)
            .map_err(|e| MementoError::storage(format!("manifest corrupt: {e}")))?;
        let mut entries = BTreeMap::new();
        if let Some(done) = doc.get("completed").and_then(|j| j.as_obj()) {
            for (id, entry) in done {
                let value = entry.get("value").cloned();
                let failed_message = entry
                    .get("failed")
                    .and_then(|j| j.as_str())
                    .map(|s| s.to_string());
                entries.insert(
                    TaskId(id.clone()),
                    CheckpointEntry {
                        id: TaskId(id.clone()),
                        value,
                        failed_message,
                        duration_secs: entry
                            .get("duration_secs")
                            .and_then(|j| j.as_f64())
                            .unwrap_or(0.0),
                        attempts: entry
                            .get("attempts")
                            .and_then(|j| j.as_i64())
                            .unwrap_or(1) as u32,
                    },
                );
            }
        }
        Ok(CheckpointStore {
            run_dir,
            backing: Backing::Dir,
            matrix_fingerprint: matrix_fingerprint.to_string(),
            version: version.to_string(),
            total_tasks: std::sync::atomic::AtomicUsize::new(total_tasks),
            flush_every: flush_every.max(1),
            storage: WireFormat::default(),
            exps: BTreeMap::new(),
            stored_exps: Self::parse_exps(doc.get("exps")),
            inner: Mutex::new(Inner { entries, dirty_since_flush: 0 }),
        })
    }

    /// True if a manifest exists under `run_dir`.
    pub fn exists(run_dir: &Path) -> bool {
        run_dir.join("manifest.json").exists()
    }

    /// Final task count, recorded once the lazy expansion is exhausted.
    /// The next flush persists it; until then the manifest carries the
    /// count known at creation time (0 for streaming runs).
    pub fn set_total(&self, total: usize) {
        self.total_tasks
            .store(total, std::sync::atomic::Ordering::Relaxed);
    }

    /// The currently known task total.
    pub fn total(&self) -> usize {
        self.total_tasks.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The checkpoint's run directory.
    pub fn run_dir(&self) -> &Path {
        &self.run_dir
    }

    /// Ids of successfully completed tasks (resume skips these).
    pub fn completed_success_ids(&self) -> Vec<TaskId> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .values()
            .filter(|e| e.succeeded())
            .map(|e| e.id.clone())
            .collect()
    }

    /// Ids recorded as failed (resume re-runs these by default).
    pub fn failed_ids(&self) -> Vec<TaskId> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .values()
            .filter(|e| !e.succeeded())
            .map(|e| e.id.clone())
            .collect()
    }

    /// The stored entry for a task, if present.
    pub fn entry(&self, id: &TaskId) -> Option<CheckpointEntry> {
        self.inner.lock().unwrap().entries.get(id).cloned()
    }

    /// Tasks recorded in the manifest so far.
    pub fn completed_count(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Records a task completion and flushes if the flush interval elapsed.
    pub fn record(
        &self,
        id: &TaskId,
        value: Option<&Json>,
        failed_message: Option<&str>,
        duration_secs: f64,
        attempts: u32,
    ) -> Result<(), MementoError> {
        let should_flush = {
            let mut inner = self.inner.lock().unwrap();
            inner.entries.insert(
                id.clone(),
                CheckpointEntry {
                    id: id.clone(),
                    value: value.cloned(),
                    failed_message: failed_message.map(|s| s.to_string()),
                    duration_secs,
                    attempts,
                },
            );
            inner.dirty_since_flush += 1;
            inner.dirty_since_flush >= self.flush_every
        };
        match &self.backing {
            Backing::Dir => {
                if should_flush {
                    // Interval flushes skip the fsync: losing the most
                    // recent manifest version to a power cut merely
                    // re-runs the tasks recorded since the previous
                    // version — exactly the contract `flush_every`
                    // already implies. The end-of-run [`CheckpointStore::flush`]
                    // is durable. (§Perf-L3: fsync-per-flush was
                    // 2.8ms/task at flush_every=1.)
                    self.flush_opts(false)?;
                }
            }
            Backing::Store(store, run) => {
                // Log backing: each completion is one appended record, so
                // there is no manifest to rewrite — the flush interval
                // degrades to an fsync cadence with the same crash
                // contract (at most `flush_every - 1` completions re-run).
                let mut fields: Vec<(&str, Json)> = vec![
                    ("duration_secs", Json::Num(duration_secs)),
                    ("attempts", Json::int(attempts as i64)),
                ];
                if let Some(v) = value {
                    fields.push(("value", v.clone()));
                }
                if let Some(m) = failed_message {
                    fields.push(("failed", Json::str(m)));
                }
                store
                    .put_ck_entry(run, &id.0, &Json::obj(fields))
                    .map_err(|e| MementoError::storage(format!("store checkpoint: {e}")))?;
                if should_flush {
                    self.inner.lock().unwrap().dirty_since_flush = 0;
                    store
                        .sync()
                        .map_err(|e| MementoError::storage(format!("sync store: {e}")))?;
                }
            }
        }
        Ok(())
    }

    /// Atomically and durably (fsync) writes the manifest.
    pub fn flush(&self) -> Result<(), MementoError> {
        self.flush_opts(true)
    }

    fn flush_opts(&self, durable: bool) -> Result<(), MementoError> {
        if let Backing::Store(store, run) = &self.backing {
            // Completions are already in the log (appended by `record`);
            // a flush just refreshes the manifest header — whose only
            // mutable field is the task total — and optionally fsyncs.
            self.inner.lock().unwrap().dirty_since_flush = 0;
            let mut header = vec![
                ("matrix_fingerprint", Json::str(self.matrix_fingerprint.clone())),
                ("version", Json::str(self.version.clone())),
                (
                    "total_tasks",
                    Json::int(self.total_tasks.load(std::sync::atomic::Ordering::Relaxed) as i64),
                ),
            ];
            if !self.exps.is_empty() {
                header.push(("exps", self.exps_json()));
            }
            let header = Json::obj(header);
            store
                .put_manifest(run, &header)
                .map_err(|e| MementoError::storage(format!("store manifest: {e}")))?;
            if durable {
                store
                    .sync()
                    .map_err(|e| MementoError::storage(format!("sync store: {e}")))?;
            }
            return Ok(());
        }
        let doc = {
            let mut inner = self.inner.lock().unwrap();
            inner.dirty_since_flush = 0;
            let completed = Json::Obj(
                inner
                    .entries
                    .values()
                    .map(|e| {
                        let mut fields: Vec<(&str, Json)> = vec![
                            ("duration_secs", Json::Num(e.duration_secs)),
                            ("attempts", Json::int(e.attempts as i64)),
                        ];
                        if let Some(v) = &e.value {
                            fields.push(("value", v.clone()));
                        }
                        if let Some(m) = &e.failed_message {
                            fields.push(("failed", Json::str(m.clone())));
                        }
                        (e.id.0.clone(), Json::obj(fields))
                    })
                    .collect(),
            );
            let mut fields = vec![
                ("matrix_fingerprint", Json::str(self.matrix_fingerprint.clone())),
                ("version", Json::str(self.version.clone())),
                (
                    "total_tasks",
                    Json::int(self.total_tasks.load(std::sync::atomic::Ordering::Relaxed) as i64),
                ),
            ];
            if !self.exps.is_empty() {
                fields.push(("exps", self.exps_json()));
            }
            fields.push(("completed", completed));
            Json::obj(fields)
        };
        // Compact serialization (tagged binary by default): the manifest
        // is rewritten on every flush, so byte count is on the hot path;
        // every reader (`resume`, `memento status`) auto-detects the form.
        let bytes = codec::write_document(&doc, self.storage);
        let path = self.run_dir.join("manifest.json");
        if durable {
            atomic_write(&path, &bytes)
        } else {
            crate::util::fs::atomic_write_nosync(&path, &bytes)
        }
        .map_err(|e| MementoError::storage(format!("write manifest: {e}")))
    }

    // ---- in-task partial progress ---------------------------------------

    fn progress_path(&self, id: &TaskId) -> PathBuf {
        self.run_dir.join("progress").join(format!("{id}.json"))
    }

    /// Persists a task's partial progress (crash-safe).
    pub fn save_progress(&self, id: &TaskId, value: &Json) {
        let bytes = codec::write_document(value, self.storage);
        let _ = atomic_write(&self.progress_path(id), &bytes);
    }

    /// Restores partial progress, if present and parsable (either
    /// encoding, auto-detected).
    pub fn load_progress(&self, id: &TaskId) -> Option<Json> {
        let bytes = std::fs::read(self.progress_path(id)).ok()?;
        codec::read_document(&bytes).ok()
    }

    /// Drops a task's progress file (after successful completion).
    pub fn clear_progress(&self, id: &TaskId) {
        let _ = std::fs::remove_file(self.progress_path(id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fs::TempDir;

    fn tid(n: u8) -> TaskId {
        TaskId(format!("{n:064x}"))
    }

    #[test]
    fn create_writes_manifest() {
        let td = TempDir::new("ckpt").unwrap();
        let _s = CheckpointStore::create(td.join("run"), "fp", "v1", 10, 1).unwrap();
        assert!(CheckpointStore::exists(&td.join("run")));
    }

    #[test]
    fn record_resume_roundtrip() {
        let td = TempDir::new("ckpt2").unwrap();
        {
            let s = CheckpointStore::create(td.join("run"), "fp", "v1", 3, 1).unwrap();
            s.record(&tid(1), Some(&Json::int(10)), None, 0.5, 1).unwrap();
            s.record(&tid(2), None, Some("boom"), 0.2, 3).unwrap();
        }
        let s = CheckpointStore::resume(td.join("run"), "fp", "v1", 3, 1).unwrap();
        assert_eq!(s.completed_count(), 2);
        assert_eq!(s.completed_success_ids(), vec![tid(1)]);
        assert_eq!(s.failed_ids(), vec![tid(2)]);
        let e1 = s.entry(&tid(1)).unwrap();
        assert_eq!(e1.value, Some(Json::int(10)));
        assert!((e1.duration_secs - 0.5).abs() < 1e-12);
        let e2 = s.entry(&tid(2)).unwrap();
        assert_eq!(e2.failed_message.as_deref(), Some("boom"));
        assert_eq!(e2.attempts, 3);
    }

    #[test]
    fn resume_rejects_wrong_matrix_or_version() {
        let td = TempDir::new("ckpt3").unwrap();
        CheckpointStore::create(td.join("run"), "fp-a", "v1", 1, 1).unwrap();
        let err =
            CheckpointStore::resume(td.join("run"), "fp-b", "v1", 1, 1).unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
        let err =
            CheckpointStore::resume(td.join("run"), "fp-a", "v2", 1, 1).unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
        assert!(CheckpointStore::resume(td.join("run"), "fp-a", "v1", 1, 1).is_ok());
    }

    #[test]
    fn mismatch_gate_materializes_nothing() {
        // The lazy-probe guarantee: refusing a wrong-matrix manifest must
        // not build any Json tree from it, however many entries it holds.
        let td = TempDir::new("ckpt-lazy").unwrap();
        {
            let s = CheckpointStore::create(td.join("run"), "fp-a", "v1", 50, 1).unwrap();
            for n in 0..50 {
                s.record(&tid(n), Some(&Json::int(n as i64)), None, 0.1, 1).unwrap();
            }
        }
        let before = crate::util::scan::materialized_count();
        let err = CheckpointStore::resume(td.join("run"), "fp-b", "v1", 50, 1).unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)));
        assert_eq!(
            crate::util::scan::materialized_count(),
            before,
            "mismatch path must not materialize any manifest subtree"
        );
    }

    #[test]
    fn json_manifest_and_progress_from_older_stores_resume_identically() {
        let td = TempDir::new("ckpt-json").unwrap();
        let run = td.join("run");
        // An "older" store: everything written as JSON text.
        {
            let s = CheckpointStore::create(&run, "fp", "v1", 3, 1)
                .unwrap()
                .storage_format(WireFormat::Json);
            s.record(&tid(1), Some(&Json::int(10)), None, 0.5, 1).unwrap();
            s.record(&tid(2), None, Some("boom"), 0.2, 3).unwrap();
            s.save_progress(&tid(3), &Json::obj(vec![("fold", Json::int(2))]));
            let bytes = std::fs::read(run.join("manifest.json")).unwrap();
            assert_eq!(bytes[0], b'{', "Json storage must stay plain text");
        }
        // A current (binary-default) store resumes it with identical
        // accounting, reads its JSON progress, and converges the manifest
        // to binary at the next flush.
        let s = CheckpointStore::resume(&run, "fp", "v1", 3, 1).unwrap();
        assert_eq!(s.completed_count(), 2);
        assert_eq!(s.completed_success_ids(), vec![tid(1)]);
        assert_eq!(s.failed_ids(), vec![tid(2)]);
        assert_eq!(s.entry(&tid(1)).unwrap().value, Some(Json::int(10)));
        assert_eq!(s.entry(&tid(2)).unwrap().failed_message.as_deref(), Some("boom"));
        assert_eq!(
            s.load_progress(&tid(3)).unwrap().get("fold").unwrap().as_i64(),
            Some(2)
        );
        s.flush().unwrap();
        let bytes = std::fs::read(run.join("manifest.json")).unwrap();
        assert!(crate::util::codec::is_binary(&bytes), "default flush is binary");
        // And the binary manifest resumes in turn.
        let again = CheckpointStore::resume(&run, "fp", "v1", 3, 1).unwrap();
        assert_eq!(again.completed_count(), 2);
    }

    #[test]
    fn resume_missing_manifest_fails() {
        let td = TempDir::new("ckpt4").unwrap();
        assert!(CheckpointStore::resume(td.join("nope"), "fp", "v1", 1, 1).is_err());
    }

    #[test]
    fn flush_interval_batches_writes() {
        let td = TempDir::new("ckpt5").unwrap();
        let run = td.join("run");
        let s = CheckpointStore::create(&run, "fp", "v1", 10, 5).unwrap();
        for n in 0..4 {
            s.record(&tid(n), Some(&Json::int(n as i64)), None, 0.0, 1).unwrap();
        }
        // Not yet flushed: a resume sees nothing.
        let peek = CheckpointStore::resume(&run, "fp", "v1", 10, 5).unwrap();
        assert_eq!(peek.completed_count(), 0);
        // 5th record crosses the interval.
        s.record(&tid(4), Some(&Json::int(4)), None, 0.0, 1).unwrap();
        let peek = CheckpointStore::resume(&run, "fp", "v1", 10, 5).unwrap();
        assert_eq!(peek.completed_count(), 5);
        // explicit flush picks up stragglers
        s.record(&tid(5), Some(&Json::int(5)), None, 0.0, 1).unwrap();
        s.flush().unwrap();
        let peek = CheckpointStore::resume(&run, "fp", "v1", 10, 5).unwrap();
        assert_eq!(peek.completed_count(), 6);
    }

    #[test]
    fn progress_files_roundtrip() {
        let td = TempDir::new("ckpt6").unwrap();
        let s = CheckpointStore::create(td.join("run"), "fp", "v1", 1, 1).unwrap();
        let id = tid(9);
        assert!(s.load_progress(&id).is_none());
        s.save_progress(&id, &Json::obj(vec![("fold", Json::int(3))]));
        assert_eq!(
            s.load_progress(&id).unwrap().get("fold").unwrap().as_i64(),
            Some(3)
        );
        s.clear_progress(&id);
        assert!(s.load_progress(&id).is_none());
    }

    #[test]
    fn concurrent_records() {
        let td = TempDir::new("ckpt7").unwrap();
        let s = std::sync::Arc::new(
            CheckpointStore::create(td.join("run"), "fp", "v1", 100, 10).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for n in 0..25u8 {
                    s.record(&tid(t * 25 + n), Some(&Json::int(n as i64)), None, 0.0, 1)
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.completed_count(), 100);
        let resumed =
            CheckpointStore::resume(s.run_dir(), "fp", "v1", 100, 10).unwrap();
        assert_eq!(resumed.completed_count(), 100);
    }

    #[test]
    fn store_backed_record_resume_roundtrip() {
        let td = TempDir::new("ckpt-store").unwrap();
        let store = ResultStore::open(td.join("store")).unwrap();
        {
            let s = CheckpointStore::create_in_store(
                Arc::clone(&store),
                "exp-1",
                td.join("run"),
                "fp",
                "v1",
                3,
                1,
            )
            .unwrap();
            assert_eq!(s.run_label(), Some("exp-1"));
            s.record(&tid(1), Some(&Json::int(10)), None, 0.5, 1).unwrap();
            s.record(&tid(2), None, Some("boom"), 0.2, 3).unwrap();
            s.flush().unwrap();
            // Progress scratch still works in store mode.
            s.save_progress(&tid(3), &Json::obj(vec![("fold", Json::int(2))]));
            assert_eq!(
                s.load_progress(&tid(3)).unwrap().get("fold").unwrap().as_i64(),
                Some(2)
            );
        }
        assert!(CheckpointStore::exists_in_store(&store, "exp-1"));
        assert!(!CheckpointStore::exists_in_store(&store, "exp-2"));
        let s = CheckpointStore::resume_in_store(
            Arc::clone(&store),
            "exp-1",
            td.join("run"),
            "fp",
            "v1",
            3,
            1,
        )
        .unwrap();
        assert_eq!(s.completed_count(), 2);
        assert_eq!(s.completed_success_ids(), vec![tid(1)]);
        assert_eq!(s.failed_ids(), vec![tid(2)]);
        let e1 = s.entry(&tid(1)).unwrap();
        assert_eq!(e1.value, Some(Json::int(10)));
        let e2 = s.entry(&tid(2)).unwrap();
        assert_eq!(e2.failed_message.as_deref(), Some("boom"));
        assert_eq!(e2.attempts, 3);
        // And the records survive a cold reopen of the store itself.
        drop(s);
        drop(store);
        let store = ResultStore::open(td.join("store")).unwrap();
        let s = CheckpointStore::resume_in_store(
            store, "exp-1", td.join("run"), "fp", "v1", 3, 1,
        )
        .unwrap();
        assert_eq!(s.completed_count(), 2);
    }

    #[test]
    fn store_backed_resume_gates_on_matrix_and_version() {
        let td = TempDir::new("ckpt-store-gate").unwrap();
        let store = ResultStore::open(td.join("store")).unwrap();
        CheckpointStore::create_in_store(
            Arc::clone(&store), "exp", td.join("run"), "fp-a", "v1", 1, 1,
        )
        .unwrap();
        let err = CheckpointStore::resume_in_store(
            Arc::clone(&store), "exp", td.join("run"), "fp-b", "v1", 1, 1,
        )
        .unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
        let err = CheckpointStore::resume_in_store(
            Arc::clone(&store), "exp", td.join("run"), "fp-a", "v2", 1, 1,
        )
        .unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
        assert!(CheckpointStore::resume_in_store(
            Arc::clone(&store), "exp", td.join("run"), "fp-a", "v1", 1, 1,
        )
        .is_ok());
        // An unknown run label is a storage error, not a mismatch.
        let err = CheckpointStore::resume_in_store(
            store, "other", td.join("run"), "fp-a", "v1", 1, 1,
        )
        .unwrap_err();
        assert!(!matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
    }

    #[test]
    fn store_backed_create_clears_previous_label() {
        let td = TempDir::new("ckpt-store-reuse").unwrap();
        let store = ResultStore::open(td.join("store")).unwrap();
        {
            let s = CheckpointStore::create_in_store(
                Arc::clone(&store), "exp", td.join("run"), "fp", "v1", 2, 1,
            )
            .unwrap();
            s.record(&tid(1), Some(&Json::int(1)), None, 0.0, 1).unwrap();
            s.record(&tid(2), Some(&Json::int(2)), None, 0.0, 1).unwrap();
        }
        // Re-creating under the same label starts from zero entries…
        let s = CheckpointStore::create_in_store(
            Arc::clone(&store), "exp", td.join("run"), "fp", "v2", 2, 1,
        )
        .unwrap();
        assert_eq!(s.completed_count(), 0);
        s.record(&tid(9), Some(&Json::int(9)), None, 0.0, 1).unwrap();
        drop(s);
        // …and a resume sees only the fresh run's records.
        let s = CheckpointStore::resume_in_store(
            store, "exp", td.join("run"), "fp", "v2", 2, 1,
        )
        .unwrap();
        assert_eq!(s.completed_count(), 1);
        assert_eq!(s.completed_success_ids(), vec![tid(9)]);
    }

    fn exps(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
        pairs
            .iter()
            .map(|(n, v)| (n.to_string(), v.to_string()))
            .collect()
    }

    #[test]
    fn exps_header_roundtrips_and_gates_on_version_drift() {
        let td = TempDir::new("ckpt-exps").unwrap();
        {
            let s = CheckpointStore::create(td.join("run"), "fp", "v1", 1, 1)
                .unwrap()
                .with_exps(exps(&[("echo", "v1"), ("grid", "v2")]));
            s.flush().unwrap();
        }
        let s = CheckpointStore::resume(td.join("run"), "fp", "v1", 1, 1).unwrap();
        assert_eq!(s.stored_exps(), &exps(&[("echo", "v1"), ("grid", "v2")]));
        // Intersection semantics: identical versions pass, as do names
        // only one side knows about.
        s.verify_exps(&exps(&[("echo", "v1"), ("grid", "v2")])).unwrap();
        s.verify_exps(&exps(&[("echo", "v1"), ("new", "v9")])).unwrap();
        s.verify_exps(&BTreeMap::new()).unwrap();
        // A shared name at a different version is refused.
        let err = s.verify_exps(&exps(&[("grid", "v3")])).unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
        assert!(err.to_string().contains("'grid'"), "{err}");
    }

    #[test]
    fn pre_registry_manifest_resumes_with_no_exps_gate() {
        // The fingerprint-compatibility rule for run dirs: a manifest
        // written without an `exps` header (pre-registry versions, and
        // every single-experiment run since) resumes under any registry —
        // the gate has nothing to compare.
        let td = TempDir::new("ckpt-exps-legacy").unwrap();
        {
            let s = CheckpointStore::create(td.join("run"), "fp", "v1", 1, 1).unwrap();
            s.record(&tid(1), Some(&Json::int(1)), None, 0.0, 1).unwrap();
        }
        let s = CheckpointStore::resume(td.join("run"), "fp", "v1", 1, 1).unwrap();
        assert!(s.stored_exps().is_empty());
        s.verify_exps(&exps(&[("anything", "v7")])).unwrap();
        assert_eq!(s.completed_count(), 1);
    }

    #[test]
    fn store_backed_exps_header_roundtrips() {
        let td = TempDir::new("ckpt-exps-store").unwrap();
        let store = ResultStore::open(td.join("store")).unwrap();
        {
            let s = CheckpointStore::create_in_store(
                Arc::clone(&store), "exp", td.join("run"), "fp", "v1", 1, 1,
            )
            .unwrap()
            .with_exps(exps(&[("echo", "v1")]));
            s.flush().unwrap();
        }
        let s = CheckpointStore::resume_in_store(
            store, "exp", td.join("run"), "fp", "v1", 1, 1,
        )
        .unwrap();
        assert_eq!(s.stored_exps(), &exps(&[("echo", "v1")]));
        let err = s.verify_exps(&exps(&[("echo", "v2")])).unwrap_err();
        assert!(matches!(err, MementoError::CheckpointMismatch(_)), "{err}");
    }
}
