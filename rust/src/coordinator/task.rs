//! Tasks: one fully-assigned parameter combination plus its identity hash.
//!
//! The paper (§3): "Each parameter is assigned a hash value when generating
//! the tasks" — task identity is what makes caching and checkpoint resume
//! sound. Here a [`TaskId`] is the SHA-256 of the *canonical JSON* of the
//! parameter assignment plus an experiment-function version salt, so:
//! - the same combination always hashes the same (cache hits across runs),
//! - changing the experiment code (bumping `version`) invalidates old
//!   cached results without deleting them.

use crate::config::value::ParamValue;
use crate::coordinator::error::MementoError;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Hex SHA-256 helper used for task ids and matrix fingerprints
/// (delegates to the in-tree [`crate::util::sha256`] implementation).
pub fn sha256_hex(bytes: &[u8]) -> String {
    crate::util::sha256::sha256_hex(bytes)
}

/// Content-addressed task identity (64 hex chars).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub String);

impl TaskId {
    /// Short prefix for human-facing logs.
    pub fn short(&self) -> &str {
        &self.0[..12.min(self.0.len())]
    }
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// A named experiment reference carried by a task: which registered
/// experiment executes it, and that experiment's version (the hash salt
/// replacing the run-wide version for named tasks — see [`TaskSpec::id`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpRef {
    /// Registered experiment name (see `crate::experiments::registry`).
    pub name: String,
    /// The experiment entry's version; bumping it invalidates cached
    /// results of this experiment without touching any other entry's.
    pub version: String,
}

/// A fully-assigned parameter combination.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Assignment in the matrix's declaration order.
    pub params: Vec<(String, ParamValue)>,
    /// Position in the expansion order (stable for a given matrix).
    pub index: usize,
    /// The named experiment this task targets. `None` means the implicit
    /// single-experiment run (the pre-registry behavior): any worker can
    /// execute it and the id hash stays byte-identical to what older
    /// versions computed, so pre-registry caches/checkpoints restore.
    pub exp: Option<ExpRef>,
}

impl TaskSpec {
    /// Computes the task id. For an unnamed task, `version` (the run-wide
    /// experiment version) salts the hash so stale cache entries are never
    /// reused after a code change (the §3 "update the code and rerun"
    /// workflow) — and the hashed document is byte-identical to what
    /// pre-registry versions produced, so their caches stay valid. For a
    /// named task the experiment's own name and version salt the hash
    /// instead: two registry entries never collide on the same params, and
    /// bumping one entry's version invalidates only that experiment's
    /// cached results.
    pub fn id(&self, version: &str) -> TaskId {
        let obj: BTreeMap<String, Json> = self
            .params
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        let doc = match &self.exp {
            None => Json::obj(vec![
                ("params", Json::Obj(obj)),
                ("version", Json::str(version)),
            ]),
            Some(e) => Json::obj(vec![
                ("exp", Json::str(e.name.clone())),
                ("params", Json::Obj(obj)),
                ("version", Json::str(e.version.clone())),
            ]),
        };
        TaskId(sha256_hex(doc.canonical().as_bytes()))
    }

    /// Value of a parameter by name.
    pub fn get(&self, name: &str) -> Option<&ParamValue> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
    }

    /// `k=v, k=v` rendering for logs and failure records.
    pub fn label(&self) -> String {
        self.params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// String context pairs (used by [`crate::coordinator::error::TaskFailure`]).
    pub fn param_strings(&self) -> Vec<(String, String)> {
        self.params
            .iter()
            .map(|(k, v)| (k.clone(), v.to_string()))
            .collect()
    }

    /// Serializes the assignment as a JSON object. A named task also
    /// carries its experiment name under the reserved `"exp"` key, so
    /// cache entries and store records written for one experiment are
    /// attributable (and queryable) by name; unnamed tasks serialize
    /// exactly as pre-registry versions did.
    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = self
            .params
            .iter()
            .map(|(k, v)| (k.clone(), v.to_json()))
            .collect();
        if let Some(e) = &self.exp {
            obj.insert("exp".to_string(), Json::str(e.name.clone()));
        }
        Json::Obj(obj)
    }
}

/// Everything a running experiment can see: its parameters, the run-wide
/// settings, a fork-safe RNG seed, and a scratch checkpoint slot.
///
/// This is the Rust analogue of the paper's `context` argument: "we access
/// the input parameters for this task", "settings … can be accessed by each
/// task", "specify the outputs that should be checkpointed".
pub struct TaskContext {
    /// This task's parameter assignment.
    pub spec: TaskSpec,
    /// The matrix's run-wide settings.
    pub settings: Arc<BTreeMap<String, Json>>,
    /// Derived from the run seed and task id; identical across re-runs.
    pub seed: u64,
    /// Attempt number, 1-based (visible so experiments can log retries).
    pub attempt: u32,
    /// Partial-progress slot persisted by the checkpoint store between
    /// attempts/resumes (see [`TaskContext::save_progress`]).
    progress: std::sync::Mutex<Option<Json>>,
    progress_sink: Option<Arc<dyn Fn(&TaskId, &Json) + Send + Sync>>,
    task_id: TaskId,
}

impl TaskContext {
    /// Assembles a context for one attempt (normally done by the
    /// scheduler/worker, not user code).
    pub fn new(
        spec: TaskSpec,
        settings: Arc<BTreeMap<String, Json>>,
        seed: u64,
        attempt: u32,
        task_id: TaskId,
        restored: Option<Json>,
        progress_sink: Option<Arc<dyn Fn(&TaskId, &Json) + Send + Sync>>,
    ) -> Self {
        TaskContext {
            spec,
            settings,
            seed,
            attempt,
            progress: std::sync::Mutex::new(restored),
            progress_sink,
            task_id,
        }
    }

    /// This task's content-hash identity.
    pub fn id(&self) -> &TaskId {
        &self.task_id
    }

    // ---- typed parameter accessors --------------------------------------

    /// The raw parameter value; `Err` when the task has no such parameter.
    pub fn param(&self, name: &str) -> Result<&ParamValue, MementoError> {
        self.spec.get(name).ok_or_else(|| {
            MementoError::experiment(format!("task has no parameter '{name}'"))
        })
    }

    /// The parameter as a string.
    pub fn param_str(&self, name: &str) -> Result<&str, MementoError> {
        self.param(name)?.as_str().ok_or_else(|| {
            MementoError::experiment(format!("parameter '{name}' is not a string"))
        })
    }

    /// The parameter as an integer.
    pub fn param_i64(&self, name: &str) -> Result<i64, MementoError> {
        self.param(name)?.as_i64().ok_or_else(|| {
            MementoError::experiment(format!("parameter '{name}' is not an integer"))
        })
    }

    /// The parameter as a float (integers coerce).
    pub fn param_f64(&self, name: &str) -> Result<f64, MementoError> {
        self.param(name)?.as_f64().ok_or_else(|| {
            MementoError::experiment(format!("parameter '{name}' is not numeric"))
        })
    }

    /// The parameter as a boolean.
    pub fn param_bool(&self, name: &str) -> Result<bool, MementoError> {
        self.param(name)?.as_bool().ok_or_else(|| {
            MementoError::experiment(format!("parameter '{name}' is not a bool"))
        })
    }

    // ---- settings --------------------------------------------------------

    /// The raw run-wide setting, if present.
    pub fn setting(&self, name: &str) -> Option<&Json> {
        self.settings.get(name)
    }

    /// The setting as an integer, with a default.
    pub fn setting_i64(&self, name: &str, default: i64) -> i64 {
        self.settings
            .get(name)
            .and_then(|j| j.as_i64())
            .unwrap_or(default)
    }

    /// The setting as a float, with a default.
    pub fn setting_f64(&self, name: &str, default: f64) -> f64 {
        self.settings
            .get(name)
            .and_then(|j| j.as_f64())
            .unwrap_or(default)
    }

    // ---- in-task checkpointing -------------------------------------------

    /// Persists partial progress (e.g. "folds 0..3 done, partial scores").
    /// On retry or resume the same task sees it via [`TaskContext::restored`].
    pub fn save_progress(&self, value: Json) {
        if let Some(sink) = &self.progress_sink {
            sink(&self.task_id, &value);
        }
        *self.progress.lock().unwrap() = Some(value);
    }

    /// Progress restored from a previous attempt/run, if any.
    pub fn restored(&self) -> Option<Json> {
        self.progress.lock().unwrap().clone()
    }
}

/// Derives a per-task seed from the run seed and task id (first 8 bytes of
/// the id hash XOR run seed) — stable across resumes, independent across
/// tasks.
pub fn task_seed(run_seed: u64, id: &TaskId) -> u64 {
    let mut bytes = [0u8; 8];
    for (i, chunk) in id.0.as_bytes().chunks(2).take(8).enumerate() {
        let hex = std::str::from_utf8(chunk).unwrap_or("00");
        bytes[i] = u8::from_str_radix(hex, 16).unwrap_or(0);
    }
    run_seed ^ u64::from_le_bytes(bytes)
}

/// Monotonic counter for unique run directories.
static RUN_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Generates a unique run id: `run-<pid>-<counter>`.
pub fn fresh_run_id() -> String {
    format!(
        "run-{}-{}",
        std::process::id(),
        RUN_COUNTER.fetch_add(1, Ordering::Relaxed)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};

    fn spec() -> TaskSpec {
        TaskSpec {
            params: vec![
                ("dataset".into(), pv_str("wine")),
                ("model".into(), pv_str("SVC")),
                ("n".into(), pv_int(5)),
            ],
            index: 3,
            exp: None,
        }
    }

    #[test]
    fn id_is_stable_and_order_independent() {
        let a = spec();
        let mut b = spec();
        b.params.reverse();
        b.index = 99; // index must not affect identity
        assert_eq!(a.id("v1"), b.id("v1"));
    }

    #[test]
    fn id_changes_with_version_and_params() {
        let a = spec();
        assert_ne!(a.id("v1"), a.id("v2"));
        let mut c = spec();
        c.params[2].1 = pv_int(6);
        assert_ne!(a.id("v1"), c.id("v1"));
    }

    #[test]
    fn unnamed_id_matches_pre_registry_hash_bytes() {
        // The fingerprint compatibility rule: an unnamed task must hash
        // exactly the document older versions hashed, so pre-registry
        // caches and checkpoints restore with zero executions.
        let legacy = r#"{"params":{"dataset":"wine","model":"SVC","n":5},"version":"v1"}"#;
        assert_eq!(spec().id("v1").0, sha256_hex(legacy.as_bytes()));
    }

    #[test]
    fn named_id_salts_with_exp_name_and_entry_version() {
        let mut named = spec();
        named.exp = Some(ExpRef { name: "echo".into(), version: "e1".into() });
        // Diverges from the unnamed id regardless of the run version…
        assert_ne!(named.id("v1"), spec().id("v1"));
        // …ignores the run version entirely (the entry version is the salt)…
        assert_eq!(named.id("v1"), named.id("v2"));
        // …and changes with either the name or the entry version.
        let mut other_name = named.clone();
        other_name.exp.as_mut().unwrap().name = "grid".into();
        assert_ne!(named.id("v1"), other_name.id("v1"));
        let mut other_ver = named.clone();
        other_ver.exp.as_mut().unwrap().version = "e2".into();
        assert_ne!(named.id("v1"), other_ver.id("v1"));
        // The named document is the same canonical shape with the exp keys.
        let doc = r#"{"exp":"echo","params":{"dataset":"wine","model":"SVC","n":5},"version":"e1"}"#;
        assert_eq!(named.id("v1").0, sha256_hex(doc.as_bytes()));
    }

    #[test]
    fn to_json_carries_exp_name_only_when_named() {
        assert_eq!(spec().to_json().get("exp"), None);
        let mut named = spec();
        named.exp = Some(ExpRef { name: "echo".into(), version: "e1".into() });
        assert_eq!(
            named.to_json().get("exp").and_then(|j| j.as_str()),
            Some("echo")
        );
    }

    #[test]
    fn id_shape() {
        let id = spec().id("v1");
        assert_eq!(id.0.len(), 64);
        assert!(id.0.chars().all(|c| c.is_ascii_hexdigit()));
        assert_eq!(id.short().len(), 12);
    }

    #[test]
    fn label_and_get() {
        let s = spec();
        assert_eq!(s.label(), "dataset=wine, model=SVC, n=5");
        assert_eq!(s.get("model"), Some(&pv_str("SVC")));
        assert_eq!(s.get("nope"), None);
    }

    #[test]
    fn context_typed_accessors() {
        let s = spec();
        let id = s.id("v1");
        let mut settings = BTreeMap::new();
        settings.insert("n_fold".to_string(), Json::int(5));
        let ctx = TaskContext::new(s, Arc::new(settings), 42, 1, id, None, None);
        assert_eq!(ctx.param_str("dataset").unwrap(), "wine");
        assert_eq!(ctx.param_i64("n").unwrap(), 5);
        assert_eq!(ctx.param_f64("n").unwrap(), 5.0);
        assert!(ctx.param_str("n").is_err());
        assert!(ctx.param("missing").is_err());
        assert_eq!(ctx.setting_i64("n_fold", 3), 5);
        assert_eq!(ctx.setting_i64("other", 3), 3);
        assert_eq!(ctx.setting_f64("other", 0.5), 0.5);
    }

    #[test]
    fn progress_roundtrip_and_sink() {
        let s = spec();
        let id = s.id("v1");
        let seen = Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
        let seen2 = Arc::clone(&seen);
        let sink: Arc<dyn Fn(&TaskId, &Json) + Send + Sync> =
            Arc::new(move |tid, j| seen2.lock().unwrap().push(format!("{tid}:{j}")));
        let ctx = TaskContext::new(
            s,
            Arc::new(BTreeMap::new()),
            0,
            1,
            id.clone(),
            Some(Json::int(2)),
            Some(sink),
        );
        assert_eq!(ctx.restored(), Some(Json::int(2)));
        ctx.save_progress(Json::int(3));
        assert_eq!(ctx.restored(), Some(Json::int(3)));
        assert_eq!(seen.lock().unwrap().len(), 1);
        assert!(seen.lock().unwrap()[0].starts_with(&id.0));
    }

    #[test]
    fn task_seed_stable_and_distinct() {
        let a = spec().id("v1");
        let mut other = spec();
        other.params[0].1 = pv_str("digits");
        let b = other.id("v1");
        assert_eq!(task_seed(7, &a), task_seed(7, &a));
        assert_ne!(task_seed(7, &a), task_seed(7, &b));
        assert_ne!(task_seed(7, &a), task_seed(8, &a));
    }

    #[test]
    fn fresh_run_ids_unique() {
        let a = fresh_run_id();
        let b = fresh_run_id();
        assert_ne!(a, b);
    }
}
