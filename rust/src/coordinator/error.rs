//! Error types and per-task failure records.
//!
//! Memento's reliability story (§2) rests on *error tracing*: when one task
//! among dozens fails, the user must see exactly which parameter combination
//! failed, why, and after how many attempts — without losing the other
//! tasks' results. [`TaskFailure`] is that record; [`MementoError`] covers
//! everything else (configuration, I/O, runtime).

use std::fmt;

/// Top-level library error.
///
/// `Display`/`Error` are hand-implemented: the offline image has no
/// crates.io access, so `thiserror` is not available.
#[derive(Debug)]
pub enum MementoError {
    /// Invalid configuration matrix or config file.
    Config(String),

    /// Persistence (cache/checkpoint) I/O problems.
    Storage(String),

    /// A checkpoint manifest that does not match the matrix being run.
    CheckpointMismatch(String),

    /// Errors raised by the user's experiment function.
    Experiment(String),

    /// PJRT / artifact runtime errors.
    Runtime(String),

    /// Inter-process execution errors (worker spawn/handshake/protocol).
    Ipc(String),

    /// A run was asked to continue but was already poisoned by fail-fast.
    Aborted(String),
}

impl fmt::Display for MementoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MementoError::Config(m) => write!(f, "config error: {m}"),
            MementoError::Storage(m) => write!(f, "storage error: {m}"),
            MementoError::CheckpointMismatch(m) => write!(f, "checkpoint mismatch: {m}"),
            MementoError::Experiment(m) => write!(f, "experiment error: {m}"),
            MementoError::Runtime(m) => write!(f, "runtime error: {m}"),
            MementoError::Ipc(m) => write!(f, "ipc error: {m}"),
            MementoError::Aborted(m) => write!(f, "run aborted: {m}"),
        }
    }
}

impl std::error::Error for MementoError {}

impl MementoError {
    /// A [`MementoError::Config`] from any message.
    pub fn config(msg: impl Into<String>) -> Self {
        MementoError::Config(msg.into())
    }
    /// A [`MementoError::Storage`] from any message.
    pub fn storage(msg: impl Into<String>) -> Self {
        MementoError::Storage(msg.into())
    }
    /// A [`MementoError::Experiment`] from any message.
    pub fn experiment(msg: impl Into<String>) -> Self {
        MementoError::Experiment(msg.into())
    }
    /// A [`MementoError::Runtime`] from any message.
    pub fn runtime(msg: impl Into<String>) -> Self {
        MementoError::Runtime(msg.into())
    }
    /// A [`MementoError::Ipc`] from any message.
    pub fn ipc(msg: impl Into<String>) -> Self {
        MementoError::Ipc(msg.into())
    }
}

/// How a task failed: an `Err` from the experiment function, a panic, or —
/// under the process-isolated/distributed backends — the death of the
/// worker executing it or a lapsed per-task wall-clock budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureKind {
    /// The experiment function returned an error.
    Error,
    /// The experiment function panicked; the panic was contained.
    Panic,
    /// The worker process executing the task died (segfault, abort, OOM
    /// kill, `kill -9`, dropped connection). Only produced by
    /// [`crate::ipc::supervisor`]; in-process threads cannot survive such
    /// a failure to report it.
    Crash,
    /// The attempt exceeded the per-task wall-clock budget
    /// (`--task-timeout`) and was stopped by the supervisor. Distinct
    /// from [`FailureKind::Crash`]: a timeout is the task's fault, not
    /// the worker's, and never consumes the worker crash budget.
    Timeout,
    /// The task named an experiment no available worker has registered
    /// (see `crate::experiments::registry`). A capability mismatch is a
    /// dispatch problem, not a worker fault: it never consumes the crash
    /// budget, and the failure message names the missing experiment.
    UnknownExperiment,
}

impl fmt::Display for FailureKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FailureKind::Error => write!(f, "error"),
            FailureKind::Panic => write!(f, "panic"),
            FailureKind::Crash => write!(f, "crash"),
            FailureKind::Timeout => write!(f, "timeout"),
            FailureKind::UnknownExperiment => write!(f, "unknown-experiment"),
        }
    }
}

/// A complete failure record for one task attempt sequence.
#[derive(Debug, Clone)]
pub struct TaskFailure {
    /// How the task failed.
    pub kind: FailureKind,
    /// Human-readable message extracted from the error/panic payload.
    pub message: String,
    /// `param=value` context of the failing task, for the §3 "which
    /// combination broke" question.
    pub params: Vec<(String, String)>,
    /// Total attempts made (1 = no retries configured or first try fatal).
    pub attempts: u32,
}

impl TaskFailure {
    /// One-line rendering used by notification providers and reports.
    pub fn summary(&self) -> String {
        let ctx = self
            .params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "[{}] after {} attempt(s) at ({ctx}): {}",
            self.kind, self.attempts, self.message
        )
    }
}

impl fmt::Display for TaskFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.summary())
    }
}

/// Extracts a printable message from a `catch_unwind` payload.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_constructors_and_display() {
        assert_eq!(
            MementoError::config("bad").to_string(),
            "config error: bad"
        );
        assert_eq!(
            MementoError::storage("disk").to_string(),
            "storage error: disk"
        );
        assert_eq!(
            MementoError::experiment("x").to_string(),
            "experiment error: x"
        );
        assert_eq!(
            MementoError::runtime("pjrt").to_string(),
            "runtime error: pjrt"
        );
    }

    #[test]
    fn failure_summary_has_context() {
        let f = TaskFailure {
            kind: FailureKind::Panic,
            message: "boom".into(),
            params: vec![
                ("dataset".into(), "wine".into()),
                ("model".into(), "SVC".into()),
            ],
            attempts: 3,
        };
        let s = f.summary();
        assert!(s.contains("panic"), "{s}");
        assert!(s.contains("dataset=wine, model=SVC"), "{s}");
        assert!(s.contains("3 attempt"), "{s}");
        assert!(s.contains("boom"), "{s}");
        assert_eq!(format!("{f}"), s);
    }

    #[test]
    fn panic_message_extraction() {
        let static_payload: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(&*static_payload), "static str");
        let string_payload: Box<dyn std::any::Any + Send> = Box::new("owned".to_string());
        assert_eq!(panic_message(&*string_payload), "owned");
        let weird: Box<dyn std::any::Any + Send> = Box::new(42u8);
        assert_eq!(panic_message(&*weird), "non-string panic payload");
    }
}
