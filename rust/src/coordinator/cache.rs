//! Content-addressed result cache.
//!
//! "To avoid running duplicate experiments, we specify to restore
//! checkpoints if available" (§3). The cache maps a [`TaskId`] (hash of the
//! parameter assignment + experiment version) to the task's result value on
//! disk: one JSON file per entry under `<dir>/<id>.json`, written atomically.
//!
//! Corruption tolerance: an unreadable/unparsable entry behaves as a miss
//! (and is counted), never as an error — a half-written file from a crash
//! must not wedge the rerun whose whole purpose is to recover from that
//! crash.

use crate::coordinator::task::{TaskId, TaskSpec};
use crate::util::fs::atomic_write;
use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Hit/miss/corruption counters (shared across worker threads).
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub writes: AtomicU64,
    pub corrupt: AtomicU64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.writes.load(Ordering::Relaxed),
            self.corrupt.load(Ordering::Relaxed),
        )
    }
}

/// On-disk result cache. Thread-safe: all methods take `&self`.
pub struct ResultCache {
    dir: PathBuf,
    stats: CacheStats,
    /// fsync entries on write. Default **false**: cache entries are
    /// recomputable, so losing one to a power cut is a miss, not
    /// corruption — and skipping the fsync makes `put` ~5-10× cheaper
    /// (see EXPERIMENTS.md §Perf-L3). Opt in via [`ResultCache::durable`].
    fsync: bool,
}

impl ResultCache {
    /// Opens (creating if needed) a cache directory.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<ResultCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(ResultCache { dir, stats: CacheStats::default(), fsync: false })
    }

    /// Enables fsync-per-entry durability.
    pub fn durable(mut self, yes: bool) -> Self {
        self.fsync = yes;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn path_of(&self, id: &TaskId) -> PathBuf {
        self.dir.join(format!("{id}.json"))
    }

    /// Looks up a cached value. Any read/parse problem counts as a miss.
    pub fn get(&self, id: &TaskId) -> Option<Json> {
        let path = self.path_of(id);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match parse(&text) {
            Ok(doc) => match doc.get("value") {
                Some(v) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    Some(v.clone())
                }
                None => {
                    self.stats.corrupt.fetch_add(1, Ordering::Relaxed);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
            },
            Err(_) => {
                self.stats.corrupt.fetch_add(1, Ordering::Relaxed);
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// True if an entry exists on disk (without counting a hit/miss).
    pub fn contains(&self, id: &TaskId) -> bool {
        self.path_of(id).exists()
    }

    /// Stores a value with its parameter context (the context makes cache
    /// files self-describing for post-hoc inspection).
    pub fn put(&self, id: &TaskId, spec: &TaskSpec, value: &Json) -> std::io::Result<()> {
        let doc = Json::obj(vec![
            ("id", Json::str(id.0.clone())),
            ("params", spec.to_json()),
            ("value", value.clone()),
        ]);
        let bytes = doc.to_string();
        if self.fsync {
            atomic_write(&self.path_of(id), bytes.as_bytes())?;
        } else {
            crate::util::fs::atomic_write_nosync(&self.path_of(id), bytes.as_bytes())?;
        }
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Removes a single entry (used when a task's code version is known
    /// stale); missing entries are fine.
    pub fn invalidate(&self, id: &TaskId) {
        let _ = std::fs::remove_file(self.path_of(id));
    }

    /// Number of entries currently on disk.
    pub fn len(&self) -> usize {
        crate::util::fs::list_files_with_ext(&self.dir, "json")
            .map(|v| v.len())
            .unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deletes every entry.
    pub fn clear(&self) -> std::io::Result<()> {
        for f in crate::util::fs::list_files_with_ext(&self.dir, "json")? {
            std::fs::remove_file(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};
    use crate::util::fs::TempDir;

    fn spec(n: i64) -> TaskSpec {
        TaskSpec {
            params: vec![("model".into(), pv_str("SVC")), ("n".into(), pv_int(n))],
            index: 0,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let td = TempDir::new("cache").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        assert!(cache.get(&id).is_none());
        cache.put(&id, &s, &Json::obj(vec![("accuracy", Json::Num(0.93))])).unwrap();
        let v = cache.get(&id).unwrap();
        assert_eq!(v.get("accuracy").unwrap().as_f64(), Some(0.93));
        let (hits, misses, writes, corrupt) = cache.stats().snapshot();
        assert_eq!((hits, misses, writes, corrupt), (1, 1, 1, 0));
        assert!((cache.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_ids_do_not_collide() {
        let td = TempDir::new("cache2").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        for n in 0..10 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        assert_eq!(cache.len(), 10);
        for n in 0..10 {
            assert_eq!(cache.get(&spec(n).id("v1")).unwrap().as_i64(), Some(n));
        }
    }

    #[test]
    fn version_salting_separates_entries() {
        let td = TempDir::new("cache3").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        cache.put(&s.id("v1"), &s, &Json::int(1)).unwrap();
        assert!(cache.get(&s.id("v2")).is_none(), "v2 must miss");
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let td = TempDir::new("cache4").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        crate::util::fs::atomic_write(
            &td.path().join(format!("{id}.json")),
            b"{ this is not json",
        )
        .unwrap();
        assert!(cache.get(&id).is_none());
        let (_, _, _, corrupt) = cache.stats().snapshot();
        assert_eq!(corrupt, 1);
        // entry missing "value" is also corrupt
        crate::util::fs::atomic_write(
            &td.path().join(format!("{id}.json")),
            b"{\"id\": \"x\"}",
        )
        .unwrap();
        assert!(cache.get(&id).is_none());
        assert_eq!(cache.stats().snapshot().3, 2);
    }

    #[test]
    fn invalidate_and_clear() {
        let td = TempDir::new("cache5").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        cache.put(&id, &s, &Json::int(1)).unwrap();
        assert!(cache.contains(&id));
        cache.invalidate(&id);
        assert!(!cache.contains(&id));
        cache.invalidate(&id); // idempotent
        for n in 0..5 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        cache.clear().unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn concurrent_put_get() {
        let td = TempDir::new("cache6").unwrap();
        let cache = std::sync::Arc::new(ResultCache::open(td.path()).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = std::sync::Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                for n in 0..25 {
                    let s = spec(t * 100 + n);
                    let id = s.id("v1");
                    c.put(&id, &s, &Json::int(n)).unwrap();
                    assert_eq!(c.get(&id).unwrap().as_i64(), Some(n));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 100);
    }
}
