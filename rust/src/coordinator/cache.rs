//! Content-addressed result cache — **two-tier**: a sharded in-memory
//! index over the existing atomic on-disk store.
//!
//! "To avoid running duplicate experiments, we specify to restore
//! checkpoints if available" (§3). The cache maps a [`TaskId`] (hash of the
//! parameter assignment + experiment version) to the task's result value.
//!
//! # Tiers
//!
//! - **Memory** — `SHARDS` mutex-guarded hash maps keyed by id. A warm
//!   `get` clones the value out of the map and never touches the
//!   filesystem; `len`/`is_empty`/`contains` are O(1) map operations
//!   instead of a directory scan per call. Sharding (by a hash of the id)
//!   keeps worker threads on different locks.
//! - **Disk** — one of two backings, auto-detected by
//!   [`ResultCache::open`]:
//!   - *Per-entry directory* (the original layout): one file per entry
//!     under `<dir>/<id>.json`, written atomically. Entries are tagged
//!     binary ([`crate::util::codec`]) by default — and compact JSON
//!     under [`ResultCache::storage_format`]`(WireFormat::Json)` — with
//!     the format auto-detected per file on read, so directories written
//!     by older (JSON-only) versions keep hitting.
//!   - *Segment-log store* ([`crate::store::ResultStore`]): entries are
//!     records in an append-only cross-run result database shared by
//!     many runs ([`ResultCache::open_store`], or `open` over a
//!     directory containing segment files). Same semantics, plus
//!     content-hash dedup accounting and `memento query` over the
//!     accumulated results.
//!
//!   Either way `put` is write-through (disk first, then memory), so
//!   crash behaviour is unchanged: the disk tier remains the source of
//!   truth and the memory tier is a cache of it. A cold read extracts
//!   just the `value` field with the lazy scanner
//!   ([`crate::util::scan`]) — the entry's id/params context is skipped,
//!   never parsed.
//!
//! Opening a cache over a pre-existing directory scans it **once** and
//! indexes every entry as *present-on-disk-but-not-loaded*; the first `get`
//! of such an entry reads and promotes it. Entries written behind the
//! cache's back (another process, tests poking files in) are still found —
//! an id missing from the index falls through to a disk probe — but they
//! are never indexed or promoted by reads (a read racing `invalidate` must
//! not resurrect an entry), so they stay on the disk path until the cache
//! is reopened.
//!
//! **Single-writer mode** ([`ResultCache::exclusive`]) drops that
//! behind-the-back tolerance: when the handle's owner is known to be the
//! only writer (e.g. the process-isolation supervisor — workers never
//! touch the store), the index is authoritative and a cold miss returns
//! without any filesystem probe at all.
//!
//! **Eviction is LRU**: residency under the byte budget is ordered by
//! last *use* (touch-on-get), not insertion, so sweep workloads that
//! revisit a parameter neighbourhood keep their hot working set resident.
//! Recency is tracked by per-entry generation numbers in a lazy queue —
//! a touch appends a fresh `(key, gen)` pair and stale pairs are skipped
//! at eviction time and periodically compacted, keeping both `get` and
//! `put` O(1) amortized with no linked-list juggling.
//!
//! Corruption tolerance is unchanged: an unreadable/unparsable entry
//! behaves as a miss (and is counted), never as an error — a half-written
//! file from a crash must not wedge the rerun whose whole purpose is to
//! recover from that crash.

use crate::coordinator::task::{TaskId, TaskSpec};
use crate::store::ResultStore;
use crate::util::codec::{self, WireFormat};
use crate::util::fs::atomic_write;
use crate::util::json::Json;
use crate::util::scan::Scanner;
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of independent memory-tier shards (power of two, small enough
/// that an idle cache costs nothing, large enough that 8–32 workers rarely
/// collide on a lock).
const SHARDS: usize = 16;

/// Default memory-tier budget per shard (16 MiB × 16 shards = 256 MiB
/// total). Experiment results are usually small metric objects, so this
/// keeps whole sweeps resident; runs with multi-MB results degrade
/// gracefully to the disk tier instead of growing without bound. Tune with
/// [`ResultCache::with_memory_budget`].
const DEFAULT_MEM_BUDGET_PER_SHARD: usize = 16 << 20;

/// Memory-tier slot for one id.
enum Slot {
    /// Value resident in memory (warm hits never touch disk). The `usize`
    /// is the serialized entry size used for budget accounting; the `u64`
    /// is the recency generation — it matches exactly one entry in the
    /// shard's eviction queue, which is what makes stale queue pairs
    /// detectable in O(1).
    Loaded(Json, usize, u64),
    /// Entry known to exist on disk but not read yet (pre-existing dir,
    /// demoted under memory pressure, or too large to keep resident).
    /// Counts toward `len()`.
    OnDisk,
}

/// Hit/miss/corruption counters (shared across worker threads).
#[derive(Debug, Default)]
pub struct CacheStats {
    /// Successful lookups (memory- and disk-tier combined).
    pub hits: AtomicU64,
    /// Lookups that found nothing.
    pub misses: AtomicU64,
    /// Entries written (both tiers, write-through).
    pub writes: AtomicU64,
    /// On-disk entries that failed to parse (treated as misses).
    pub corrupt: AtomicU64,
    /// Hits served from the memory tier (no filesystem I/O at all).
    pub mem_hits: AtomicU64,
    /// Hits that had to read + parse the on-disk entry.
    pub disk_hits: AtomicU64,
}

impl CacheStats {
    /// Fraction of lookups that hit (0.0 with no traffic).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    /// `(hits, misses, writes, corrupt)` as of now.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.writes.load(Ordering::Relaxed),
            self.corrupt.load(Ordering::Relaxed),
        )
    }

    /// `(mem_hits, disk_hits)` — how warm the memory tier is.
    pub fn tier_snapshot(&self) -> (u64, u64) {
        (
            self.mem_hits.load(Ordering::Relaxed),
            self.disk_hits.load(Ordering::Relaxed),
        )
    }
}

/// One memory-tier shard: the slot map plus O(1) residency accounting and
/// a recency-ordered eviction queue, so neither the budget check nor
/// victim selection ever scans the map.
#[derive(Default)]
struct Shard {
    map: HashMap<String, Slot>,
    /// `(key, generation)` pairs in recency order (least recent at the
    /// front). A pair is live iff its generation matches the slot's
    /// current generation; touches/demotions/invalidations leave stale
    /// pairs behind, which eviction skips lazily and compaction drops.
    eviction_queue: VecDeque<(String, u64)>,
    /// Monotonic recency counter; bumped on every insert and touch.
    gen: u64,
    /// Number of `Slot::Loaded` entries in `map`.
    resident: usize,
    /// Sum of the serialized sizes of `Slot::Loaded` entries.
    resident_bytes: usize,
}

/// Disk tier implementation behind the memory tier.
enum Backing {
    /// One atomic file per entry under the cache directory.
    Dir,
    /// Records in a shared segment-log store ([`crate::store`]).
    Store(Arc<ResultStore>),
}

/// Two-tier result cache. Thread-safe: all methods take `&self`.
pub struct ResultCache {
    dir: PathBuf,
    backing: Backing,
    stats: CacheStats,
    /// fsync entries on write. Default **false**: cache entries are
    /// recomputable, so losing one to a power cut is a miss, not
    /// corruption — and skipping the fsync makes `put` ~5-10× cheaper
    /// (see EXPERIMENTS.md §Perf-L3). Opt in via [`ResultCache::durable`].
    fsync: bool,
    /// Single-writer mode: the in-memory index is authoritative, so an id
    /// absent from it misses without a disk probe. Sound only while no
    /// other process writes the directory; see [`ResultCache::exclusive`].
    exclusive: AtomicBool,
    /// Memory tier: sharded id → slot maps.
    shards: Vec<Mutex<Shard>>,
    /// Byte budget per shard for resident values; exceeding it demotes the
    /// oldest residents to [`Slot::OnDisk`] (safe: disk is the source of
    /// truth), and a single value larger than the whole shard budget is
    /// never kept resident at all.
    mem_budget_per_shard: usize,
    /// On-disk entry encoding for *writes* (reads always auto-detect).
    storage: WireFormat,
}

fn shard_of(key: &str) -> usize {
    // FNV-1a; ids are uniform SHA-256 hex but this also handles any key.
    let mut h = 0xcbf29ce484222325u64;
    for b in key.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    (h as usize) % SHARDS
}

impl ResultCache {
    /// Opens (creating if needed) a cache directory. Pre-existing entries
    /// are indexed (one directory scan, ever) but not loaded into memory
    /// until first touched. The disk-tier layout is auto-detected: a
    /// directory holding segment files (`seg-*.log`) opens store-backed,
    /// anything else opens (or creates) the per-entry layout — so caches
    /// written by either version keep working unchanged.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<ResultCache> {
        let dir = dir.into();
        if ResultStore::is_store_dir(&dir) {
            return Ok(ResultCache::open_store(ResultStore::open(&dir)?));
        }
        std::fs::create_dir_all(&dir)?;
        let shards: Vec<Mutex<Shard>> =
            (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect();
        for path in crate::util::fs::list_files_with_ext(&dir, "json")? {
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                shards[shard_of(stem)]
                    .lock()
                    .unwrap()
                    .map
                    .insert(stem.to_string(), Slot::OnDisk);
            }
        }
        Ok(ResultCache {
            dir,
            backing: Backing::Dir,
            stats: CacheStats::default(),
            fsync: false,
            exclusive: AtomicBool::new(false),
            shards,
            mem_budget_per_shard: DEFAULT_MEM_BUDGET_PER_SHARD,
            storage: WireFormat::default(),
        })
    }

    /// Opens a cache whose disk tier is a shared segment-log store —
    /// results land as records in the cross-run database instead of
    /// per-entry files. The memory-tier index is seeded from the store's
    /// live result ids, so `len`/`contains`/exclusive-mode semantics are
    /// identical to the directory backing.
    pub fn open_store(store: Arc<ResultStore>) -> ResultCache {
        let shards: Vec<Mutex<Shard>> =
            (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect();
        for id in store.result_ids() {
            shards[shard_of(&id)].lock().unwrap().map.insert(id, Slot::OnDisk);
        }
        ResultCache {
            dir: store.dir(),
            backing: Backing::Store(store),
            stats: CacheStats::default(),
            fsync: false,
            exclusive: AtomicBool::new(false),
            shards,
            mem_budget_per_shard: DEFAULT_MEM_BUDGET_PER_SHARD,
            storage: WireFormat::default(),
        }
    }

    /// The shared store behind this cache, when store-backed.
    pub fn store_handle(&self) -> Option<Arc<ResultStore>> {
        match &self.backing {
            Backing::Dir => None,
            Backing::Store(s) => Some(Arc::clone(s)),
        }
    }

    /// Chooses the on-disk encoding for new entries: tagged binary (the
    /// default) or compact JSON for human-debuggable stores. Reads
    /// auto-detect per file either way, so mixed directories are fine.
    pub fn storage_format(mut self, format: WireFormat) -> Self {
        self.storage = format;
        if let Backing::Store(store) = &self.backing {
            store.set_wire(format);
        }
        self
    }

    /// Enables fsync-per-entry durability.
    pub fn durable(mut self, yes: bool) -> Self {
        self.fsync = yes;
        self
    }

    /// Declares this handle the **only writer** of the cache directory:
    /// the in-memory index (seeded by the one-time scan in
    /// [`ResultCache::open`] and kept current by `put`/`invalidate`)
    /// becomes authoritative, and a cold miss returns without probing the
    /// filesystem at all. Do not enable while another process writes the
    /// same directory — its entries would be invisible until reopen.
    pub fn exclusive(self) -> Self {
        self.set_exclusive(true);
        self
    }

    /// In-place variant of [`ResultCache::exclusive`] for shared handles
    /// (the process-isolation supervisor enables it on the run's cache:
    /// workers never write the store directly).
    pub fn set_exclusive(&self, yes: bool) {
        self.exclusive.store(yes, Ordering::Relaxed);
    }

    /// True when single-writer mode is on.
    pub fn is_exclusive(&self) -> bool {
        self.exclusive.load(Ordering::Relaxed)
    }

    /// Bounds the memory tier to ~`total_bytes` of resident serialized
    /// values (split across shards; default 256 MiB). Excess entries
    /// demote to the disk tier oldest-first — they are never lost. Lower
    /// this for runs whose result values are large, raise it to keep a
    /// bigger working set warm.
    pub fn with_memory_budget(mut self, total_bytes: usize) -> Self {
        self.mem_budget_per_shard = (total_bytes / SHARDS).max(1);
        self
    }

    /// The cache's on-disk directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Shared hit/miss/tier counters.
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    fn path_of(&self, id: &TaskId) -> PathBuf {
        self.dir.join(format!("{id}.json"))
    }

    /// Looks up a cached value. Warm entries are served from the memory
    /// tier without any filesystem access (and are *touched*: LRU
    /// eviction keeps recently-used entries resident); cold-but-indexed
    /// entries read the disk tier once and promote. Any read/parse
    /// problem counts as a miss. In [`ResultCache::exclusive`] mode an id
    /// absent from the index misses with zero filesystem work.
    pub fn get(&self, id: &TaskId) -> Option<Json> {
        let shard = &self.shards[shard_of(&id.0)];
        {
            let mut sh = shard.lock().unwrap();
            let warm = match sh.map.get(&id.0) {
                Some(Slot::Loaded(v, _, _)) => Some(v.clone()),
                Some(Slot::OnDisk) => None,
                None if self.exclusive.load(Ordering::Relaxed) => {
                    // Single-writer mode: the index is authoritative, so
                    // this is a definitive (allocation- and I/O-free) miss.
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                None => None,
            };
            if let Some(v) = warm {
                self.touch_locked(&mut sh, &id.0);
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats.mem_hits.fetch_add(1, Ordering::Relaxed);
                return Some(v);
            }
        }
        // Cold path: disk tier. Read outside the shard lock so a slow disk
        // never blocks warm hits on the same shard. Both backings honour
        // the same lazy-scan contract: only the `value` subtree is ever
        // materialized; the entry's id/params context is skipped.
        let (value, approx_bytes) = match &self.backing {
            Backing::Dir => {
                let bytes = match std::fs::read(self.path_of(id)) {
                    Ok(b) => b,
                    Err(_) => {
                        // Entry gone from disk: drop a stale OnDisk marker
                        // if any so len() converges (a Loaded entry
                        // re-inserted by a concurrent put stays).
                        self.drop_stale_marker(&id.0);
                        self.stats.misses.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                };
                let len = bytes.len();
                let value = (|| {
                    let scanner = Scanner::new(&bytes)?;
                    match scanner.field("value")? {
                        Some(v) => v.materialize().map(Some),
                        None => Ok(None),
                    }
                })();
                match value {
                    Ok(Some(v)) => (v, len),
                    Ok(None) | Err(_) => {
                        self.stats.corrupt.fetch_add(1, Ordering::Relaxed);
                        self.stats.misses.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                }
            }
            Backing::Store(store) => match store.get_result(&id.0) {
                Ok(Some(v)) => {
                    // The store read the frame already; approximate the
                    // residency cost by the value's serialized size.
                    let len = v.to_string().len();
                    (v, len)
                }
                Ok(None) => {
                    self.drop_stale_marker(&id.0);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                Err(_) => {
                    self.stats.corrupt.fetch_add(1, Ordering::Relaxed);
                    self.stats.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            },
        };
        self.stats.hits.fetch_add(1, Ordering::Relaxed);
        self.stats.disk_hits.fetch_add(1, Ordering::Relaxed);
        self.promote_if_on_disk(&id.0, value.clone(), approx_bytes);
        Some(value)
    }

    /// Removes a stale [`Slot::OnDisk`] marker after the backing reported
    /// the entry gone (a `Loaded` slot re-inserted by a concurrent put
    /// stays).
    fn drop_stale_marker(&self, key: &str) {
        let mut sh = self.shards[shard_of(key)].lock().unwrap();
        if matches!(sh.map.get(key), Some(Slot::OnDisk)) {
            sh.map.remove(key);
        }
    }

    /// Marks a resident entry as just-used: bumps its generation and
    /// appends a fresh queue pair, invalidating the old pair in place.
    /// This is the "L" in LRU — eviction pops least-recent live pairs.
    fn touch_locked(&self, sh: &mut Shard, key: &str) {
        sh.gen += 1;
        let g = sh.gen;
        match sh.map.get_mut(key) {
            Some(Slot::Loaded(_, _, slot_gen)) => *slot_gen = g,
            _ => return,
        }
        sh.eviction_queue.push_back((key.to_string(), g));
        self.maybe_compact(sh);
    }

    /// Inserts a resident value into a locked shard, then demotes
    /// least-recently-used entries until the shard is back under its byte
    /// budget. All bookkeeping is O(1) amortized: the budget check reads
    /// a counter and victims pop off the recency queue (stale pairs —
    /// touched, demoted, or invalidated since being queued — are detected
    /// by a generation mismatch and skipped).
    fn insert_loaded_locked(&self, sh: &mut Shard, key: &str, value: Json, bytes: usize) {
        // Retire accounting for a value being replaced in place.
        if let Some(Slot::Loaded(_, old, _)) = sh.map.get(key) {
            sh.resident -= 1;
            sh.resident_bytes -= *old;
        }
        if bytes > self.mem_budget_per_shard {
            // Too large to ever keep resident: index it, serve from disk.
            sh.map.insert(key.to_string(), Slot::OnDisk);
            return;
        }
        sh.gen += 1;
        let g = sh.gen;
        sh.map.insert(key.to_string(), Slot::Loaded(value, bytes, g));
        sh.resident += 1;
        sh.resident_bytes += bytes;
        sh.eviction_queue.push_back((key.to_string(), g));
        // The just-inserted key holds the newest generation at the back
        // and fits the budget alone, so this loop always terminates
        // before demoting it.
        while sh.resident_bytes > self.mem_budget_per_shard {
            let Some((victim, vg)) = sh.eviction_queue.pop_front() else { break };
            let victim_bytes = match sh.map.get(&victim) {
                Some(Slot::Loaded(_, b, lg)) if *lg == vg => *b,
                _ => continue, // stale pair (touched/demoted/invalidated)
            };
            sh.map.insert(victim, Slot::OnDisk);
            sh.resident -= 1;
            sh.resident_bytes -= victim_bytes;
        }
        self.maybe_compact(sh);
    }

    /// Drops stale queue pairs once they dominate. Generations make this
    /// trivial: a pair is live iff it matches its slot's current
    /// generation, and each resident has exactly one live pair, so the
    /// front-to-back sweep preserves recency order. Amortized O(1) per
    /// insert/touch.
    fn maybe_compact(&self, sh: &mut Shard) {
        if sh.eviction_queue.len() > 4 * sh.resident + 64 {
            let mut kept: VecDeque<(String, u64)> = VecDeque::with_capacity(sh.resident);
            while let Some((k, g)) = sh.eviction_queue.pop_front() {
                if matches!(sh.map.get(&k), Some(Slot::Loaded(_, _, lg)) if *lg == g) {
                    kept.push_back((k, g));
                }
            }
            sh.eviction_queue = kept;
        }
    }

    /// Write path: unconditionally (re)loads the entry.
    fn insert_loaded(&self, key: &str, value: Json, bytes: usize) {
        let mut sh = self.shards[shard_of(key)].lock().unwrap();
        self.insert_loaded_locked(&mut sh, key, value, bytes);
    }

    /// Read-path promotion. Only upgrades a still-indexed [`Slot::OnDisk`]
    /// entry: if a concurrent `put` already loaded a *newer* value, or a
    /// concurrent `invalidate` removed the entry, the disk read this
    /// promotion came from is stale and must not overwrite the index —
    /// otherwise the memory tier would serve the stale value forever.
    fn promote_if_on_disk(&self, key: &str, value: Json, bytes: usize) {
        let mut sh = self.shards[shard_of(key)].lock().unwrap();
        if matches!(sh.map.get(key), Some(Slot::OnDisk)) {
            self.insert_loaded_locked(&mut sh, key, value, bytes);
        }
    }

    /// True if an entry exists (without counting a hit/miss). O(1) for
    /// indexed entries; falls back to a read-only disk probe for ids
    /// written behind the cache's back (not indexed here — a probe racing
    /// `invalidate` must not resurrect the entry). In
    /// [`ResultCache::exclusive`] mode the index answer is final.
    pub fn contains(&self, id: &TaskId) -> bool {
        if self.shards[shard_of(&id.0)]
            .lock()
            .unwrap()
            .map
            .contains_key(&id.0)
        {
            return true;
        }
        if self.exclusive.load(Ordering::Relaxed) {
            return false;
        }
        match &self.backing {
            Backing::Dir => self.path_of(id).exists(),
            Backing::Store(store) => store.contains_result(&id.0),
        }
    }

    /// Stores a value with its parameter context (the context makes cache
    /// files self-describing for post-hoc inspection). A named spec
    /// additionally stamps the entry with its experiment name + entry
    /// version, so stores and migrated caches keep full provenance.
    /// Write-through: the disk entry lands first, then the memory tier
    /// picks it up.
    pub fn put(&self, id: &TaskId, spec: &TaskSpec, value: &Json) -> std::io::Result<()> {
        let exp = spec.exp.as_ref().map(|e| (e.name.as_str(), e.version.as_str()));
        let approx_bytes = match &self.backing {
            Backing::Dir => {
                let mut fields = vec![("id", Json::str(id.0.clone()))];
                if let Some((name, version)) = exp {
                    fields.push(("exp", Json::str(name)));
                    fields.push(("exp_version", Json::str(version)));
                }
                fields.push(("params", spec.to_json()));
                fields.push(("value", value.clone()));
                let doc = Json::obj(fields);
                let bytes = codec::write_document(&doc, self.storage);
                if self.fsync {
                    atomic_write(&self.path_of(id), &bytes)?;
                } else {
                    crate::util::fs::atomic_write_nosync(&self.path_of(id), &bytes)?;
                }
                bytes.len()
            }
            Backing::Store(store) => {
                store.put_result_exp(&id.0, &spec.to_json(), value, exp)?;
                if self.fsync {
                    store.sync()?;
                }
                value.to_string().len()
            }
        };
        self.insert_loaded(&id.0, value.clone(), approx_bytes);
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Removes a single entry from both tiers (used when a task's code
    /// version is known stale); missing entries are fine.
    pub fn invalidate(&self, id: &TaskId) {
        match &self.backing {
            Backing::Dir => {
                let _ = std::fs::remove_file(self.path_of(id));
            }
            Backing::Store(store) => {
                let _ = store.invalidate_result(&id.0);
            }
        }
        let mut sh = self.shards[shard_of(&id.0)].lock().unwrap();
        if let Some(Slot::Loaded(_, b, _)) = sh.map.remove(&id.0) {
            sh.resident -= 1;
            sh.resident_bytes -= b;
        }
    }

    /// Number of entries in the cache. O(1) over the in-memory index — no
    /// directory listing (the index covers pre-existing entries via the
    /// one-time scan in [`ResultCache::open`]).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// True when the cache indexes no entries.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().map.is_empty())
    }

    /// Entries currently resident in the memory tier (diagnostics).
    pub fn resident_len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().resident).sum()
    }

    /// Serialized bytes currently resident in the memory tier.
    pub fn resident_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().resident_bytes).sum()
    }

    /// Demotes every resident value to the disk tier, releasing the memory
    /// without losing entries (they reload on next `get`).
    pub fn drop_memory(&self) {
        for shard in &self.shards {
            let mut sh = shard.lock().unwrap();
            for slot in sh.map.values_mut() {
                if matches!(slot, Slot::Loaded(..)) {
                    *slot = Slot::OnDisk;
                }
            }
            sh.eviction_queue.clear();
            sh.resident = 0;
            sh.resident_bytes = 0;
        }
    }

    /// Deletes every entry from both tiers (store backing: tombstones
    /// every live result — the log keeps its history until compaction).
    pub fn clear(&self) -> std::io::Result<()> {
        match &self.backing {
            Backing::Dir => {
                for f in crate::util::fs::list_files_with_ext(&self.dir, "json")? {
                    std::fs::remove_file(f)?;
                }
            }
            Backing::Store(store) => {
                store.clear_results()?;
            }
        }
        for shard in &self.shards {
            let mut sh = shard.lock().unwrap();
            sh.map.clear();
            sh.eviction_queue.clear();
            sh.resident = 0;
            sh.resident_bytes = 0;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::{pv_int, pv_str};
    use crate::util::fs::TempDir;

    fn spec(n: i64) -> TaskSpec {
        TaskSpec {
            params: vec![("model".into(), pv_str("SVC")), ("n".into(), pv_int(n))],
            index: 0,
            exp: None,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let td = TempDir::new("cache").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        assert!(cache.get(&id).is_none());
        cache.put(&id, &s, &Json::obj(vec![("accuracy", Json::Num(0.93))])).unwrap();
        let v = cache.get(&id).unwrap();
        assert_eq!(v.get("accuracy").unwrap().as_f64(), Some(0.93));
        let (hits, misses, writes, corrupt) = cache.stats().snapshot();
        assert_eq!((hits, misses, writes, corrupt), (1, 1, 1, 0));
        assert!((cache.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn warm_hits_never_touch_disk() {
        // The acceptance check for the memory tier: after put, delete the
        // backing file out from under the cache — the value must still be
        // served (memory tier), with the hit attributed to mem_hits.
        let td = TempDir::new("cache-mem").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        cache.put(&id, &s, &Json::int(42)).unwrap();
        std::fs::remove_file(td.path().join(format!("{id}.json"))).unwrap();
        assert_eq!(cache.get(&id).unwrap().as_i64(), Some(42));
        assert_eq!(cache.get(&id).unwrap().as_i64(), Some(42));
        let (mem, disk) = cache.stats().tier_snapshot();
        assert_eq!((mem, disk), (2, 0));
    }

    #[test]
    fn preexisting_dir_is_indexed_once_and_promoted_on_get() {
        let td = TempDir::new("cache-reopen").unwrap();
        {
            let cache = ResultCache::open(td.path()).unwrap();
            for n in 0..10 {
                let s = spec(n);
                cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
            }
        }
        // Fresh handle over the same dir: len is right without any put.
        let cache = ResultCache::open(td.path()).unwrap();
        assert_eq!(cache.len(), 10);
        assert_eq!(cache.resident_len(), 0, "indexed but not loaded");
        // First get reads disk; second is a pure memory hit.
        let id = spec(3).id("v1");
        assert_eq!(cache.get(&id).unwrap().as_i64(), Some(3));
        assert_eq!(cache.get(&id).unwrap().as_i64(), Some(3));
        let (mem, disk) = cache.stats().tier_snapshot();
        assert_eq!((mem, disk), (1, 1));
        assert_eq!(cache.resident_len(), 1);
    }

    #[test]
    fn drop_memory_demotes_without_losing_entries() {
        let td = TempDir::new("cache-demote").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        for n in 0..5 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        assert_eq!(cache.resident_len(), 5);
        cache.drop_memory();
        assert_eq!(cache.resident_len(), 0);
        assert_eq!(cache.len(), 5, "entries survive demotion");
        assert_eq!(cache.get(&spec(2).id("v1")).unwrap().as_i64(), Some(2));
        assert_eq!(cache.resident_len(), 1, "reloaded on get");
    }

    #[test]
    fn memory_budget_bounds_residency() {
        let td = TempDir::new("cache-budget").unwrap();
        // ~2 KiB per shard: each serialized entry is a few hundred bytes,
        // so only a handful stay resident per shard.
        let budget = SHARDS * 2048;
        let cache = ResultCache::open(td.path()).unwrap().with_memory_budget(budget);
        for n in 0..200 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        assert_eq!(cache.len(), 200, "all entries indexed");
        assert!(
            cache.resident_bytes() <= budget,
            "resident_bytes {} exceeds budget {budget}",
            cache.resident_bytes()
        );
        assert!(
            cache.resident_len() < 200,
            "budget must have demoted something (resident {})",
            cache.resident_len()
        );
        // Demoted entries still readable (from disk), and re-promotion
        // under the same budget stays bounded.
        for n in 0..200 {
            assert_eq!(cache.get(&spec(n).id("v1")).unwrap().as_i64(), Some(n));
        }
        assert!(cache.resident_bytes() <= budget);
    }

    #[test]
    fn oversized_value_stays_on_disk_tier() {
        let td = TempDir::new("cache-big").unwrap();
        let cache = ResultCache::open(td.path()).unwrap().with_memory_budget(SHARDS * 512);
        let s = spec(1);
        let id = s.id("v1");
        // Serialized entry far above the 512-byte shard budget.
        let big = Json::Arr(vec![Json::Num(0.123456789); 1000]);
        cache.put(&id, &s, &big).unwrap();
        assert_eq!(cache.resident_len(), 0, "oversized value must not reside");
        assert_eq!(cache.len(), 1, "still indexed");
        // Served from disk, repeatedly, without ever promoting.
        for _ in 0..2 {
            assert_eq!(cache.get(&id).unwrap().as_arr().unwrap().len(), 1000);
        }
        let (mem, disk) = cache.stats().tier_snapshot();
        assert_eq!(mem, 0);
        assert_eq!(disk, 2);
    }

    #[test]
    fn lru_touch_keeps_hot_entry_resident_through_sweep() {
        // A sweep inserts a long stream of entries under a tight budget
        // while one "hot" id is re-read before every insert. Delete the
        // hot entry's backing file: if eviction were FIFO the hot entry
        // (oldest insert) would be demoted and the next get would miss
        // (file gone); with LRU touch-on-get it must stay resident and be
        // served from memory for the whole sweep.
        let td = TempDir::new("cache-lru").unwrap();
        let cache = ResultCache::open(td.path())
            .unwrap()
            .with_memory_budget(SHARDS * 1024);
        let hot_spec = spec(9_999);
        let hot = hot_spec.id("v1");
        cache.put(&hot, &hot_spec, &Json::int(42)).unwrap();
        std::fs::remove_file(td.path().join(format!("{hot}.json"))).unwrap();
        for n in 0..320 {
            assert_eq!(
                cache.get(&hot).map(|v| v.as_i64()),
                Some(Some(42)),
                "hot entry evicted after {n} inserts (LRU broken)"
            );
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        // Budget still respected while the hot set stayed warm.
        assert!(cache.resident_bytes() <= SHARDS * 1024);
        let (_, disk) = cache.stats().tier_snapshot();
        assert_eq!(disk, 0, "hot gets must never have touched disk");
    }

    #[test]
    fn exclusive_mode_skips_disk_probe_on_cold_miss() {
        let td = TempDir::new("cache-excl").unwrap();
        let s = spec(1);
        let id = s.id("v1");
        // Two handles over the same (empty) dir: one shared, one
        // exclusive. A third handle then writes behind both their backs.
        let shared = ResultCache::open(td.path()).unwrap();
        let excl = ResultCache::open(td.path()).unwrap().exclusive();
        assert!(excl.is_exclusive());
        ResultCache::open(td.path())
            .unwrap()
            .put(&id, &s, &Json::int(7))
            .unwrap();
        // The shared handle falls through to disk and finds the foreign
        // entry; the exclusive handle trusts its (empty) index.
        assert_eq!(shared.get(&id).unwrap().as_i64(), Some(7));
        assert!(shared.contains(&id));
        assert!(excl.get(&id).is_none(), "exclusive index is authoritative");
        assert!(!excl.contains(&id));
        let (hits, misses, _, _) = excl.stats().snapshot();
        assert_eq!((hits, misses), (0, 1));
        // The exclusive handle's own writes still hit normally.
        excl.put(&id, &s, &Json::int(8)).unwrap();
        assert_eq!(excl.get(&id).unwrap().as_i64(), Some(8));
        assert!(excl.contains(&id));
        // Entries indexed at open (pre-existing dir) are served even in
        // exclusive mode.
        let reopened = ResultCache::open(td.path()).unwrap().exclusive();
        assert_eq!(reopened.len(), 1);
        assert_eq!(reopened.get(&id).unwrap().as_i64(), Some(8));
    }

    #[test]
    fn distinct_ids_do_not_collide() {
        let td = TempDir::new("cache2").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        for n in 0..10 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        assert_eq!(cache.len(), 10);
        for n in 0..10 {
            assert_eq!(cache.get(&spec(n).id("v1")).unwrap().as_i64(), Some(n));
        }
    }

    #[test]
    fn version_salting_separates_entries() {
        let td = TempDir::new("cache3").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        cache.put(&s.id("v1"), &s, &Json::int(1)).unwrap();
        assert!(cache.get(&s.id("v2")).is_none(), "v2 must miss");
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let td = TempDir::new("cache4").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        crate::util::fs::atomic_write(
            &td.path().join(format!("{id}.json")),
            b"{ this is not json",
        )
        .unwrap();
        assert!(cache.get(&id).is_none());
        let (_, _, _, corrupt) = cache.stats().snapshot();
        assert_eq!(corrupt, 1);
        // entry missing "value" is also corrupt
        crate::util::fs::atomic_write(
            &td.path().join(format!("{id}.json")),
            b"{\"id\": \"x\"}",
        )
        .unwrap();
        assert!(cache.get(&id).is_none());
        assert_eq!(cache.stats().snapshot().3, 2);
    }

    #[test]
    fn default_entries_are_binary_and_json_stores_still_hit() {
        let td = TempDir::new("cache-fmt").unwrap();
        let s = spec(1);
        let id = s.id("v1");
        // Default handle writes tagged binary…
        {
            let cache = ResultCache::open(td.path()).unwrap();
            cache.put(&id, &s, &Json::int(5)).unwrap();
            let bytes = std::fs::read(td.path().join(format!("{id}.json"))).unwrap();
            assert!(crate::util::codec::is_binary(&bytes));
        }
        // …and a fresh handle reads it back off disk (auto-detect).
        let cache = ResultCache::open(td.path()).unwrap();
        assert_eq!(cache.get(&id).unwrap().as_i64(), Some(5));

        // A pre-binary store: JSON text written the way older versions
        // did. It must hit through any handle, unchanged.
        let td2 = TempDir::new("cache-fmt-json").unwrap();
        {
            let writer = ResultCache::open(td2.path())
                .unwrap()
                .storage_format(WireFormat::Json);
            writer.put(&id, &s, &Json::int(7)).unwrap();
            let bytes = std::fs::read(td2.path().join(format!("{id}.json"))).unwrap();
            assert_eq!(bytes[0], b'{', "Json storage must stay plain text");
        }
        let reader = ResultCache::open(td2.path()).unwrap();
        assert_eq!(reader.get(&id).unwrap().as_i64(), Some(7));
    }

    #[test]
    fn cold_get_materializes_only_the_value_subtree() {
        let td = TempDir::new("cache-lazy").unwrap();
        let s = spec(1);
        let id = s.id("v1");
        for format in [WireFormat::Binary, WireFormat::Json] {
            let writer = ResultCache::open(td.path()).unwrap().storage_format(format);
            writer.put(&id, &s, &Json::obj(vec![("acc", Json::Num(0.5))])).unwrap();
            // Fresh handle ⇒ cold read: exactly one materialization (the
            // `value` subtree), no matter how much context surrounds it.
            let cache = ResultCache::open(td.path()).unwrap();
            let before = crate::util::scan::materialized_count();
            assert_eq!(
                cache.get(&id).unwrap().get("acc").unwrap().as_f64(),
                Some(0.5),
                "{format:?}"
            );
            assert_eq!(
                crate::util::scan::materialized_count() - before,
                1,
                "{format:?}: cold get must materialize exactly the value"
            );
        }
    }

    #[test]
    fn invalidate_and_clear() {
        let td = TempDir::new("cache5").unwrap();
        let cache = ResultCache::open(td.path()).unwrap();
        let s = spec(1);
        let id = s.id("v1");
        cache.put(&id, &s, &Json::int(1)).unwrap();
        assert!(cache.contains(&id));
        cache.invalidate(&id);
        assert!(!cache.contains(&id));
        cache.invalidate(&id); // idempotent
        for n in 0..5 {
            let s = spec(n);
            cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
        }
        cache.clear().unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn concurrent_put_get() {
        let td = TempDir::new("cache6").unwrap();
        let cache = std::sync::Arc::new(ResultCache::open(td.path()).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = std::sync::Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                for n in 0..25 {
                    let s = spec(t * 100 + n);
                    let id = s.id("v1");
                    c.put(&id, &s, &Json::int(n)).unwrap();
                    assert_eq!(c.get(&id).unwrap().as_i64(), Some(n));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.len(), 100);
        // All gets after a same-handle put are memory-tier hits.
        let (mem, disk) = cache.stats().tier_snapshot();
        assert_eq!(mem, 100);
        assert_eq!(disk, 0);
    }

    #[test]
    fn store_backed_cache_roundtrip_and_auto_detect() {
        let td = TempDir::new("cache-store").unwrap();
        let store = ResultStore::open(td.path()).unwrap();
        {
            let cache = ResultCache::open_store(std::sync::Arc::clone(&store));
            assert!(cache.store_handle().is_some());
            for n in 0..10 {
                let s = spec(n);
                cache.put(&s.id("v1"), &s, &Json::int(n)).unwrap();
            }
            assert_eq!(cache.len(), 10);
            assert_eq!(store.stats().live_records, 10, "entries are store records");
        }
        // `open` over the same directory auto-detects the segment layout.
        let cache = ResultCache::open(td.path()).unwrap();
        assert!(cache.store_handle().is_some());
        assert_eq!(cache.len(), 10, "index seeded from the store");
        assert_eq!(cache.resident_len(), 0);
        for n in 0..10 {
            assert_eq!(cache.get(&spec(n).id("v1")).unwrap().as_i64(), Some(n));
        }
        let (mem, disk) = cache.stats().tier_snapshot();
        assert_eq!((mem, disk), (0, 10), "cold reads come from the store");
        // Second pass is all memory-tier.
        for n in 0..10 {
            assert_eq!(cache.get(&spec(n).id("v1")).unwrap().as_i64(), Some(n));
        }
        assert_eq!(cache.stats().tier_snapshot().0, 10);
        // Invalidate tombstones the record for every handle.
        let id = spec(3).id("v1");
        cache.invalidate(&id);
        assert!(!cache.contains(&id));
        assert!(!store.contains_result(&id.0));
        // Clear wipes the rest.
        cache.clear().unwrap();
        assert!(cache.is_empty());
        assert_eq!(store.stats().live_records, 0);
    }

    #[test]
    fn store_backed_cold_get_materializes_only_the_value_subtree() {
        for format in [WireFormat::Binary, WireFormat::Json] {
            let td = TempDir::new("cache-store-lazy").unwrap();
            let s = spec(1);
            let id = s.id("v1");
            {
                let store = ResultStore::open(td.path()).unwrap();
                let writer =
                    ResultCache::open_store(store).storage_format(format);
                writer.put(&id, &s, &Json::obj(vec![("acc", Json::Num(0.5))])).unwrap();
            }
            let cache = ResultCache::open(td.path()).unwrap();
            let before = crate::util::scan::materialized_count();
            assert_eq!(
                cache.get(&id).unwrap().get("acc").unwrap().as_f64(),
                Some(0.5),
                "{format:?}"
            );
            assert_eq!(
                crate::util::scan::materialized_count() - before,
                1,
                "{format:?}: store-backed cold get must materialize exactly the value"
            );
        }
    }
}
