//! The Memento coordinator — the paper's contribution (Layer 3).
//!
//! Pipeline: [`expand`] lazily streams a
//! [`crate::config::matrix::ConfigMatrix`] into hashed
//! [`task::TaskSpec`]s; [`source`] wraps that stream in the shared
//! pull/exhaustion/drain state machine both backends consume;
//! [`scheduler`] pulls them onto a worker pool;
//! [`cache`] and [`checkpoint`] give re-run avoidance and
//! crash-resumption; [`retry`], [`notify`], [`metrics`], [`progress`] and
//! [`results`] round out the reliability/observability story. [`memento`]
//! is the user-facing façade, and [`run`] is its streaming session handle
//! (`launch → events → collect/cancel`). [`inflight`] is the cross-run
//! execute-once gate concurrent runs sharing one store install (see
//! [`crate::daemon`]).

pub mod cache;
pub mod checkpoint;
pub mod error;
pub mod expand;
pub mod inflight;
pub mod journal;
pub mod memento;
pub mod metrics;
pub mod notify;
pub mod progress;
pub mod results;
pub mod retry;
pub mod run;
pub mod scheduler;
pub mod source;
pub mod task;
