//! The shared lazy-spec-source state machine: [`DrainOnceSource`].
//!
//! Both execution backends consume the planner's lazy spec stream through
//! the same protocol: pull specs on demand behind one mutex, notice
//! exhaustion exactly once, fire a one-shot completion hook when the
//! stream (and any in-flight filtering) is truly done, and — after a
//! fail-fast abort — drain the un-started remainder for skip accounting,
//! bounded so an abort returns promptly on an astronomically large matrix.
//!
//! Before this module existed, that state machine was hand-duplicated in
//! `scheduler::SourceState` and the supervisor's `SrcState`/`pop_source` —
//! a fire-once invariant maintained twice is a latent double-drain bug.
//! `DrainOnceSource` is now the single place the exhausted latch, the
//! `on_drained` hook, and the bounded drain live; the scheduler and the
//! IPC supervisor are thin consumers.
//!
//! # The restore filter (why `outstanding` exists)
//!
//! The planner's restore stage (cache probe + checkpoint record for
//! already-completed tasks) is I/O. Running it inside the source mutex —
//! as the first streaming implementation did by fusing it into the
//! iterator — serializes restores: a resume of a mostly-complete run
//! restores single-threaded no matter how many workers pull. The source
//! therefore takes the filter as a separate stage: the mutex protects
//! **raw expansion only**, and each puller runs the filter on its own
//! specs *outside* the lock, so N workers restore N-way parallel.
//!
//! Splitting the stages reopens a race the fused design never had: the
//! iterator can run dry while another worker is still mid-filter, and
//! firing `on_drained` at that moment would publish non-final totals
//! (checkpoint `set_total`, the `RunStarted` notification gate). The
//! source closes it with an `outstanding` lease count — raw specs handed
//! out minus specs whose filter stage completed — and fires the hook only
//! once `exhausted && outstanding == 0`, i.e. when every result has been
//! merged back, exactly once.

use crate::coordinator::task::TaskSpec;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A lazy, possibly astronomically large stream of task specs. Consumers
/// never materialize it.
pub type SpecSource = Box<dyn Iterator<Item = TaskSpec> + Send>;

/// The unlocked restore stage: maps a raw spec to `Some(spec)` when it
/// still needs executing, or `None` when the filter consumed it (restored
/// from cache/checkpoint and delivered through its own side channel).
/// Runs on the pulling worker's thread, **outside** the source mutex, so
/// its cache/checkpoint I/O parallelizes across pullers.
pub type SpecFilter = Arc<dyn Fn(TaskSpec) -> Option<TaskSpec> + Send + Sync>;

/// Fired exactly once, when the source is exhausted *and* every pulled
/// spec has cleared the restore filter (totals are final).
pub type DrainedHook = Box<dyn FnOnce() + Send + Sync>;

/// Upper bound on how many raw specs a post-abort [`DrainOnceSource::drain`]
/// will enumerate for skip accounting. Bounded so an abort returns
/// promptly even on a 10¹²-combination matrix: beyond the limit the
/// remainder is left un-enumerated and reported via
/// [`DrainReport::truncated`].
pub const ABORT_DRAIN_LIMIT: usize = 100_000;

/// Largest granule [`DrainOnceSource::drain`] pulls per lock acquisition.
const DRAIN_CHUNK: usize = 64;

struct Inner {
    it: SpecSource,
    exhausted: bool,
    on_drained: Option<DrainedHook>,
}

/// What a bounded [`DrainOnceSource::drain`] accomplished.
#[derive(Debug, Clone, Copy, Default)]
pub struct DrainReport {
    /// Specs handed to the `each` callback (post-filter).
    pub skipped: usize,
    /// True when the drain hit its limit with the source still not
    /// exhausted: `skipped` is then a lower bound on the remainder.
    pub truncated: bool,
}

/// A lazy spec source with a fire-once exhaustion hook, an optional
/// unlocked restore filter, and a once-only bounded abort drain.
///
/// Guarantees, by construction:
/// 1. every raw spec is handed to exactly one puller (the mutex);
/// 2. `on_drained` fires exactly once, only after the iterator is dry
///    *and* all handed-out specs have cleared the filter stage;
/// 3. the filter runs outside the mutex — concurrent pullers filter
///    their own specs in parallel;
/// 4. [`DrainOnceSource::drain`] runs at most once per source, bounded
///    by its limit (re-entry is a no-op, so callers re-entering a drain
///    path cannot multiply the bound).
pub struct DrainOnceSource {
    inner: Mutex<Inner>,
    filter: Option<SpecFilter>,
    /// Raw specs handed out whose filter stage has not completed yet.
    /// Always 0 when no filter is installed.
    outstanding: AtomicUsize,
    /// Lock-free mirror of `Inner::exhausted`.
    exhausted: AtomicBool,
    /// Latch: the bounded abort drain runs at most once.
    drain_used: AtomicBool,
}

impl DrainOnceSource {
    /// Wraps a lazy source with an optional restore filter and fire-once
    /// exhaustion hook.
    pub fn new(
        source: SpecSource,
        filter: Option<SpecFilter>,
        on_drained: Option<DrainedHook>,
    ) -> DrainOnceSource {
        DrainOnceSource {
            inner: Mutex::new(Inner { it: source, exhausted: false, on_drained }),
            filter,
            outstanding: AtomicUsize::new(0),
            exhausted: AtomicBool::new(false),
            drain_used: AtomicBool::new(false),
        }
    }

    /// True once the underlying iterator has been seen to run dry. Note
    /// that filters may still be in flight; use `on_drained` for the
    /// "totals are final" moment.
    pub fn is_exhausted(&self) -> bool {
        self.exhausted.load(Ordering::SeqCst)
    }

    /// Pulls up to `granule` raw specs under the lock, marking exhaustion
    /// and taking out filter leases while still holding it (so `exhausted
    /// && outstanding == 0` can never be observed with specs in limbo).
    fn pull_raw(&self, granule: usize) -> Vec<TaskSpec> {
        let mut chunk = Vec::new();
        let mut src = self.inner.lock().unwrap();
        if src.exhausted {
            return chunk;
        }
        chunk.reserve(granule);
        while chunk.len() < granule {
            match src.it.next() {
                Some(s) => chunk.push(s),
                None => {
                    src.exhausted = true;
                    self.exhausted.store(true, Ordering::SeqCst);
                    break;
                }
            }
        }
        if self.filter.is_some() {
            self.outstanding.fetch_add(chunk.len(), Ordering::SeqCst);
        }
        chunk
    }

    /// Marks `n` pulled specs as having cleared the filter stage.
    fn settle(&self, n: usize) {
        if n > 0 {
            self.outstanding.fetch_sub(n, Ordering::SeqCst);
        }
    }

    /// Fires `on_drained` if (and only if) the stream is finished: the
    /// iterator dry and no filter work in flight. Safe to call
    /// opportunistically — the hook is a fire-once `Option::take` under
    /// the lock, and the callback itself runs outside it.
    fn maybe_fire(&self) {
        if !self.exhausted.load(Ordering::SeqCst)
            || self.outstanding.load(Ordering::SeqCst) != 0
        {
            return;
        }
        let hook = {
            let mut src = self.inner.lock().unwrap();
            // Re-check under the lock: a racing puller may have taken new
            // leases between the fast-path check and here (it cannot —
            // exhausted sources hand out nothing — but a racing *settle*
            // on another thread is what this serializes with).
            if self.outstanding.load(Ordering::SeqCst) != 0 {
                None
            } else {
                src.on_drained.take()
            }
        };
        if let Some(hook) = hook {
            hook();
        }
    }

    /// Pulls up to `granule` pending specs: raw expansion under the lock,
    /// restore filtering outside it. Keeps pulling while entire granules
    /// are consumed by the filter (a resume over a mostly-complete run),
    /// so a non-empty return always carries executable work. Returns empty
    /// exactly when the source is exhausted.
    pub fn pull(&self, granule: usize) -> Vec<TaskSpec> {
        let granule = granule.max(1);
        loop {
            let raw = self.pull_raw(granule);
            if raw.is_empty() {
                self.maybe_fire();
                return raw;
            }
            match &self.filter {
                None => {
                    self.maybe_fire();
                    return raw;
                }
                Some(f) => {
                    let mut pending = Vec::with_capacity(raw.len());
                    for spec in raw {
                        if let Some(s) = f(spec) {
                            pending.push(s);
                        }
                        self.settle(1);
                    }
                    self.maybe_fire();
                    if !pending.is_empty() {
                        return pending;
                    }
                    // Whole granule restored; pull again for real work.
                }
            }
        }
    }

    /// Pulls one pending spec (the process-backend dispatch shape).
    /// `None` exactly when the source is exhausted.
    pub fn pop(&self) -> Option<TaskSpec> {
        self.pull(1).into_iter().next()
    }

    /// The once-only bounded abort drain: enumerates the un-started
    /// remainder (up to `limit` **raw** specs) for skip accounting,
    /// passing each still-pending spec to `each`. Restorable specs still
    /// restore through the filter, exactly as they would have on the live
    /// path. `cancelled` is polled between specs so a cancel stops the
    /// drain immediately.
    ///
    /// A second call is a no-op (`drain_used` latch): abort paths that are
    /// re-entered per worker/slot cannot multiply the bound.
    pub fn drain(
        &self,
        limit: usize,
        each: &mut dyn FnMut(TaskSpec),
        cancelled: &dyn Fn() -> bool,
    ) -> DrainReport {
        if self.drain_used.swap(true, Ordering::SeqCst) {
            return DrainReport::default();
        }
        let mut report = DrainReport::default();
        let mut raw_seen = 0usize;
        'outer: while !cancelled() {
            if raw_seen >= limit {
                report.truncated = !self.is_exhausted();
                break;
            }
            let raw = self.pull_raw(DRAIN_CHUNK.min(limit - raw_seen));
            if raw.is_empty() {
                break;
            }
            raw_seen += raw.len();
            let mut chunk = raw.into_iter();
            while let Some(spec) = chunk.next() {
                let pending = match &self.filter {
                    None => Some(spec),
                    Some(f) => {
                        let kept = f(spec);
                        self.settle(1);
                        kept
                    }
                };
                if let Some(s) = pending {
                    report.skipped += 1;
                    each(s);
                }
                if cancelled() {
                    // Cancel forfeits the rest of this chunk's accounting,
                    // but the leases must still be released — a leaked
                    // lease would starve the fire-once hook forever.
                    if self.filter.is_some() {
                        self.settle(chunk.len());
                    }
                    break 'outer;
                }
            }
        }
        self.maybe_fire();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::value::pv_int;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn specs(n: usize) -> SpecSource {
        Box::new((0..n).map(|i| TaskSpec {
            params: vec![("i".to_string(), pv_int(i as i64))],
            index: i,
            exp: None,
        }))
    }

    fn counter_hook(fired: &Arc<AtomicUsize>) -> DrainedHook {
        let fired = Arc::clone(fired);
        Box::new(move || {
            fired.fetch_add(1, Ordering::SeqCst);
        })
    }

    #[test]
    fn pull_hands_out_every_spec_once_and_fires_once() {
        let fired = Arc::new(AtomicUsize::new(0));
        let src = DrainOnceSource::new(specs(100), None, Some(counter_hook(&fired)));
        let mut seen = Vec::new();
        loop {
            let chunk = src.pull(7);
            if chunk.is_empty() {
                break;
            }
            seen.extend(chunk.into_iter().map(|s| s.index));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        assert!(src.is_exhausted());
        // Further pulls stay empty and never re-fire.
        assert!(src.pull(8).is_empty());
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn filter_runs_outside_the_lock_and_consumes_specs() {
        // Filter restores every even spec; pull must only return odd ones
        // and still account for everything.
        let restored = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&restored);
        let filter: SpecFilter = Arc::new(move |s: TaskSpec| {
            if s.index % 2 == 0 {
                r2.fetch_add(1, Ordering::SeqCst);
                None
            } else {
                Some(s)
            }
        });
        let fired = Arc::new(AtomicUsize::new(0));
        let src = DrainOnceSource::new(specs(50), Some(filter), Some(counter_hook(&fired)));
        let mut pending = 0usize;
        while let Some(s) = src.pop() {
            assert_eq!(s.index % 2, 1);
            pending += 1;
        }
        assert_eq!(pending, 25);
        assert_eq!(restored.load(Ordering::SeqCst), 25);
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn all_restored_source_pulls_to_exhaustion_not_livelock() {
        let filter: SpecFilter = Arc::new(|_s: TaskSpec| None);
        let fired = Arc::new(AtomicUsize::new(0));
        let src = DrainOnceSource::new(specs(500), Some(filter), Some(counter_hook(&fired)));
        assert!(src.pull(16).is_empty(), "everything restored");
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn on_drained_waits_for_in_flight_filters() {
        // Worker A holds a spec in its filter while worker B exhausts the
        // source; the hook must not fire until A settles.
        use std::sync::mpsc;
        let (enter_tx, enter_rx) = mpsc::channel::<()>();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        // Mutex-wrapped so the filter is Sync on every supported toolchain
        // (mpsc endpoints only became Sync in recent Rust).
        let enter_tx = std::sync::Mutex::new(enter_tx);
        let release_rx = std::sync::Mutex::new(release_rx);
        let filter: SpecFilter = Arc::new(move |s: TaskSpec| {
            if s.index == 0 {
                let _ = enter_tx.lock().unwrap().send(());
                let _ = release_rx.lock().unwrap().recv();
            }
            Some(s)
        });
        let fired = Arc::new(AtomicUsize::new(0));
        let src = Arc::new(DrainOnceSource::new(
            specs(10),
            Some(filter),
            Some(counter_hook(&fired)),
        ));
        let a = {
            let src = Arc::clone(&src);
            std::thread::spawn(move || src.pull(1))
        };
        enter_rx.recv().unwrap(); // A is inside the filter, holding a lease
        // B drains the rest of the source to exhaustion.
        loop {
            if src.pull(4).is_empty() {
                break;
            }
        }
        assert!(src.is_exhausted());
        assert_eq!(
            fired.load(Ordering::SeqCst),
            0,
            "hook must wait for the in-flight filter"
        );
        release_tx.send(()).unwrap();
        let chunk = a.join().unwrap();
        assert_eq!(chunk.len(), 1);
        assert_eq!(fired.load(Ordering::SeqCst), 1, "fires after the last settle");
    }

    #[test]
    fn drain_is_bounded_truncated_and_once_only() {
        let src = DrainOnceSource::new(specs(10_000), None, None);
        let mut seen = 0usize;
        let r = src.drain(1_000, &mut |_s| seen += 1, &|| false);
        assert_eq!(seen, 1_000);
        assert_eq!(r.skipped, 1_000);
        assert!(r.truncated, "limit hit before exhaustion");
        // Second drain is a no-op: the once-latch keeps the bound global.
        let r2 = src.drain(1_000, &mut |_s| seen += 1, &|| false);
        assert_eq!(r2.skipped, 0);
        assert!(!r2.truncated);
        assert_eq!(seen, 1_000);
    }

    #[test]
    fn drain_respects_cancel_and_fires_hook_on_full_drain() {
        let fired = Arc::new(AtomicUsize::new(0));
        let src = DrainOnceSource::new(specs(100), None, Some(counter_hook(&fired)));
        let mut seen = 0usize;
        let r = src.drain(ABORT_DRAIN_LIMIT, &mut |_s| seen += 1, &|| false);
        assert_eq!(r.skipped, 100);
        assert!(!r.truncated);
        assert_eq!(fired.load(Ordering::SeqCst), 1, "full drain discovers exhaustion");
    }

    #[test]
    fn cancelled_drain_releases_filter_leases() {
        // Regression: a cancel mid-chunk forfeits the rest of the chunk's
        // accounting, but the filter leases must still be released — a
        // leaked lease would starve the fire-once hook forever.
        let filter: SpecFilter = Arc::new(Some);
        let fired = Arc::new(AtomicUsize::new(0));
        let src = DrainOnceSource::new(specs(200), Some(filter), Some(counter_hook(&fired)));
        let cancelled = AtomicBool::new(false);
        let r = src.drain(
            ABORT_DRAIN_LIMIT,
            &mut |_s| cancelled.store(true, Ordering::SeqCst),
            &|| cancelled.load(Ordering::SeqCst),
        );
        assert_eq!(r.skipped, 1, "cancel landed after the first spec");
        // Consuming the rest of the stream must still fire the hook.
        while !src.pull(64).is_empty() {}
        assert_eq!(fired.load(Ordering::SeqCst), 1, "leaked lease starved the hook");
    }

    #[test]
    fn drain_applies_restore_filter() {
        // Restorable specs restore during the drain (parity with the live
        // path); only still-pending ones are reported as skips.
        let restored = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&restored);
        let filter: SpecFilter = Arc::new(move |s: TaskSpec| {
            if s.index < 30 {
                r2.fetch_add(1, Ordering::SeqCst);
                None
            } else {
                Some(s)
            }
        });
        let src = DrainOnceSource::new(specs(100), Some(filter), None);
        let mut skips = 0usize;
        let r = src.drain(ABORT_DRAIN_LIMIT, &mut |_s| skips += 1, &|| false);
        assert_eq!(restored.load(Ordering::SeqCst), 30);
        assert_eq!(r.skipped, 70);
        assert_eq!(skips, 70);
    }

    // ---- property: fire-once under concurrent pulls + drains --------------

    #[test]
    fn prop_on_drained_fires_exactly_once_under_concurrency() {
        // Loom-style brute loop: varying worker counts, source sizes, and
        // filter presence, with concurrent pullers plus one drainer racing
        // each other — the hook must fire exactly once, after every lease
        // has settled, every time.
        use crate::testing::prop::check;
        check("drain-once-fire-once", 40, |g| {
            let n = g.size(0, 400);
            let workers = g.size(1, 8);
            let with_filter = g.size(0, 1) == 1;
            let with_drainer = g.size(0, 1) == 1;
            let handled = Arc::new(AtomicUsize::new(0));
            let fired = Arc::new(AtomicUsize::new(0));
            let fired_hook = Arc::clone(&fired);
            let handled_at_fire = Arc::new(AtomicUsize::new(usize::MAX));
            let hf = Arc::clone(&handled_at_fire);
            let hh = Arc::clone(&handled);
            let hook: DrainedHook = Box::new(move || {
                fired_hook.fetch_add(1, Ordering::SeqCst);
                hf.store(hh.load(Ordering::SeqCst), Ordering::SeqCst);
            });
            let filter: Option<SpecFilter> = with_filter.then(|| {
                let handled = Arc::clone(&handled);
                Arc::new(move |s: TaskSpec| {
                    handled.fetch_add(1, Ordering::SeqCst);
                    (s.index % 3 != 0).then_some(s)
                }) as SpecFilter
            });
            let src = Arc::new(DrainOnceSource::new(specs(n), filter, Some(hook)));
            let mut threads = Vec::new();
            for w in 0..workers {
                let src = Arc::clone(&src);
                let handled = Arc::clone(&handled);
                let track = !with_filter;
                threads.push(std::thread::spawn(move || loop {
                    let chunk = src.pull(1 + w % 5);
                    if chunk.is_empty() {
                        return;
                    }
                    if track {
                        handled.fetch_add(chunk.len(), Ordering::SeqCst);
                    }
                }));
            }
            if with_drainer {
                let src = Arc::clone(&src);
                let handled = Arc::clone(&handled);
                let track = !with_filter;
                threads.push(std::thread::spawn(move || {
                    src.drain(
                        ABORT_DRAIN_LIMIT,
                        &mut |_s| {
                            if track {
                                handled.fetch_add(1, Ordering::SeqCst);
                            }
                        },
                        &|| false,
                    );
                }));
            }
            for t in threads {
                t.join().unwrap();
            }
            crate::prop_assert!(
                fired.load(Ordering::SeqCst) == 1,
                "hook fired {} times (n={n}, workers={workers}, filter={with_filter})",
                fired.load(Ordering::SeqCst)
            );
            crate::prop_assert!(src.is_exhausted(), "source fully consumed");
            // When filtering, drains are counted at filter time, so by fire
            // time every raw spec must have been handled. Without a filter
            // the hook fires at exhaustion discovery (pre-settle parity
            // with the fused design), so no such claim holds.
            if with_filter {
                let at_fire = handled_at_fire.load(Ordering::SeqCst);
                crate::prop_assert!(
                    at_fire == n,
                    "hook fired with {at_fire}/{n} specs filtered"
                );
            }
            Ok(())
        });
    }
}
