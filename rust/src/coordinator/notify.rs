//! Notification providers.
//!
//! "The notification provider specifies the notification sent to the user
//! once Memento completes the tasks" (§3) — and on failures (§1: "receive
//! notifications when experiments fail or finish"). Providers receive
//! structured [`Notification`]s; four implementations ship:
//!
//! - [`ConsoleNotificationProvider`] — the paper's default, prints to stdout;
//! - [`FileNotificationProvider`] — appends JSON lines to a log file;
//! - [`MemoryNotificationProvider`] — collects in memory (tests/assertions);
//! - [`SimWebhookNotificationProvider`] — simulates a webhook/email gateway
//!   by writing one JSON file per notification to an outbox directory
//!   (substitute for a real HTTP provider on the offline image).

use crate::coordinator::error::TaskFailure;
use crate::util::json::Json;
use std::path::PathBuf;
use std::sync::Mutex;

/// A structured notification event.
#[derive(Debug, Clone)]
pub enum Notification {
    /// A run started: total tasks after exclusion, cached-skip count.
    RunStarted {
        /// Total tasks the run will account for.
        total: usize,
        /// Tasks already restored from cache/checkpoint.
        from_cache: usize,
    },
    /// One task failed (sent as failures happen, not only at the end).
    TaskFailed {
        /// The failure record (kind, message, params, attempts).
        failure: TaskFailure,
    },
    /// The run finished.
    RunFinished {
        /// Total tasks accounted for.
        total: usize,
        /// Successful tasks (restores included).
        succeeded: usize,
        /// Finally-failed tasks.
        failed: usize,
        /// Tasks restored without executing.
        from_cache: usize,
        /// Wall-clock duration in seconds.
        wall_secs: f64,
    },
}

impl Notification {
    /// One-line human rendering.
    pub fn render(&self) -> String {
        match self {
            Notification::RunStarted { total, from_cache } => format!(
                "memento: run started — {total} task(s), {from_cache} restored from cache"
            ),
            Notification::TaskFailed { failure } => {
                format!("memento: task failed — {}", failure.summary())
            }
            Notification::RunFinished { total, succeeded, failed, from_cache, wall_secs } => {
                format!(
                    "memento: run finished — {succeeded}/{total} succeeded, {failed} failed, \
                     {from_cache} cached, wall {}",
                    crate::util::time::fmt_secs(*wall_secs)
                )
            }
        }
    }

    /// Structured rendering for machine consumers.
    pub fn to_json(&self) -> Json {
        match self {
            Notification::RunStarted { total, from_cache } => Json::obj(vec![
                ("event", Json::str("run_started")),
                ("total", Json::int(*total as i64)),
                ("from_cache", Json::int(*from_cache as i64)),
            ]),
            Notification::TaskFailed { failure } => Json::obj(vec![
                ("event", Json::str("task_failed")),
                ("summary", Json::str(failure.summary())),
                ("attempts", Json::int(failure.attempts as i64)),
            ]),
            Notification::RunFinished { total, succeeded, failed, from_cache, wall_secs } => {
                Json::obj(vec![
                    ("event", Json::str("run_finished")),
                    ("total", Json::int(*total as i64)),
                    ("succeeded", Json::int(*succeeded as i64)),
                    ("failed", Json::int(*failed as i64)),
                    ("from_cache", Json::int(*from_cache as i64)),
                    ("wall_secs", Json::Num(*wall_secs)),
                ])
            }
        }
    }
}

/// Receives notifications. Implementations must be thread-safe: failures
/// are emitted from worker threads while the run is in flight.
pub trait NotificationProvider: Send + Sync {
    /// Delivers one notification (called from run/worker threads; must
    /// not block for long).
    fn notify(&self, n: &Notification);
}

/// Prints rendered notifications to stdout (the paper's
/// `ConsoleNotificationProvider`).
#[derive(Debug, Default)]
pub struct ConsoleNotificationProvider;

impl NotificationProvider for ConsoleNotificationProvider {
    fn notify(&self, n: &Notification) {
        println!("{}", n.render());
    }
}

/// Appends one JSON line per notification to a file.
pub struct FileNotificationProvider {
    path: PathBuf,
    lock: Mutex<()>,
}

impl FileNotificationProvider {
    /// Appends to (creating if needed) the log file at `path`.
    pub fn new(path: impl Into<PathBuf>) -> Self {
        FileNotificationProvider { path: path.into(), lock: Mutex::new(()) }
    }
}

impl NotificationProvider for FileNotificationProvider {
    fn notify(&self, n: &Notification) {
        use std::io::Write;
        let _g = self.lock.lock().unwrap();
        if let Some(dir) = self.path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
        {
            let _ = writeln!(f, "{}", n.to_json());
        }
    }
}

/// Collects notifications in memory; `events()` snapshots them. Test aid.
#[derive(Debug, Default)]
pub struct MemoryNotificationProvider {
    events: Mutex<Vec<Notification>>,
}

impl MemoryNotificationProvider {
    /// An empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of every notification received so far.
    pub fn events(&self) -> Vec<Notification> {
        self.events.lock().unwrap().clone()
    }

    /// Notifications received so far.
    pub fn count(&self) -> usize {
        self.events.lock().unwrap().len()
    }
}

impl NotificationProvider for MemoryNotificationProvider {
    fn notify(&self, n: &Notification) {
        self.events.lock().unwrap().push(n.clone());
    }
}

/// Simulated webhook: writes `outbox/<seq>.json` per notification.
///
/// Stands in for the real-world "send me an email/Slack ping" provider —
/// the offline image has no network, so delivery is modelled as an outbox
/// directory that an external agent would drain.
pub struct SimWebhookNotificationProvider {
    outbox: PathBuf,
    seq: Mutex<u64>,
}

impl SimWebhookNotificationProvider {
    /// Delivers into the given outbox directory.
    pub fn new(outbox: impl Into<PathBuf>) -> Self {
        SimWebhookNotificationProvider { outbox: outbox.into(), seq: Mutex::new(0) }
    }

    /// The outbox directory notifications are written into.
    pub fn outbox(&self) -> &std::path::Path {
        &self.outbox
    }
}

impl NotificationProvider for SimWebhookNotificationProvider {
    fn notify(&self, n: &Notification) {
        let mut seq = self.seq.lock().unwrap();
        let path = self.outbox.join(format!("{:06}.json", *seq));
        *seq += 1;
        let _ = crate::util::fs::atomic_write(&path, n.to_json().to_string().as_bytes());
    }
}

/// Fans one notification out to several providers.
#[derive(Default)]
pub struct MultiNotificationProvider {
    providers: Vec<Box<dyn NotificationProvider>>,
}

impl MultiNotificationProvider {
    /// An empty fan-out.
    pub fn new() -> Self {
        Self::default()
    }

    /// Adds a downstream provider.
    pub fn push(mut self, p: Box<dyn NotificationProvider>) -> Self {
        self.providers.push(p);
        self
    }
}

impl NotificationProvider for MultiNotificationProvider {
    fn notify(&self, n: &Notification) {
        for p in &self.providers {
            p.notify(n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::error::FailureKind;
    use crate::util::fs::TempDir;

    fn failure() -> TaskFailure {
        TaskFailure {
            kind: FailureKind::Error,
            message: "nan loss".into(),
            params: vec![("model".into(), "SVC".into())],
            attempts: 2,
        }
    }

    #[test]
    fn render_all_variants() {
        let started = Notification::RunStarted { total: 45, from_cache: 3 };
        assert!(started.render().contains("45 task(s)"));
        let failed = Notification::TaskFailed { failure: failure() };
        assert!(failed.render().contains("nan loss"));
        let fin = Notification::RunFinished {
            total: 45,
            succeeded: 44,
            failed: 1,
            from_cache: 3,
            wall_secs: 12.0,
        };
        let r = fin.render();
        assert!(r.contains("44/45"), "{r}");
        assert!(r.contains("1 failed"), "{r}");
    }

    #[test]
    fn json_shapes() {
        let fin = Notification::RunFinished {
            total: 2,
            succeeded: 2,
            failed: 0,
            from_cache: 1,
            wall_secs: 0.5,
        };
        let j = fin.to_json();
        assert_eq!(j.get("event").unwrap().as_str(), Some("run_finished"));
        assert_eq!(j.get("succeeded").unwrap().as_i64(), Some(2));
        let tf = Notification::TaskFailed { failure: failure() }.to_json();
        assert_eq!(tf.get("event").unwrap().as_str(), Some("task_failed"));
    }

    #[test]
    fn memory_provider_collects() {
        let p = MemoryNotificationProvider::new();
        p.notify(&Notification::RunStarted { total: 1, from_cache: 0 });
        p.notify(&Notification::TaskFailed { failure: failure() });
        assert_eq!(p.count(), 2);
        assert!(matches!(p.events()[0], Notification::RunStarted { .. }));
    }

    #[test]
    fn file_provider_appends_json_lines() {
        let td = TempDir::new("notify").unwrap();
        let path = td.join("log/notify.jsonl");
        let p = FileNotificationProvider::new(&path);
        p.notify(&Notification::RunStarted { total: 3, from_cache: 0 });
        p.notify(&Notification::RunFinished {
            total: 3,
            succeeded: 3,
            failed: 0,
            from_cache: 0,
            wall_secs: 1.0,
        });
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            crate::util::json::parse(l).unwrap();
        }
    }

    #[test]
    fn webhook_outbox_sequences() {
        let td = TempDir::new("webhook").unwrap();
        let p = SimWebhookNotificationProvider::new(td.join("outbox"));
        for _ in 0..3 {
            p.notify(&Notification::RunStarted { total: 1, from_cache: 0 });
        }
        let files = crate::util::fs::list_files_with_ext(p.outbox(), "json").unwrap();
        assert_eq!(files.len(), 3);
        assert!(files[0].file_name().unwrap().to_str().unwrap().starts_with("000000"));
    }

    #[test]
    fn multi_fans_out() {
        let mem1 = std::sync::Arc::new(MemoryNotificationProvider::new());
        let mem2 = std::sync::Arc::new(MemoryNotificationProvider::new());
        struct Fwd(std::sync::Arc<MemoryNotificationProvider>);
        impl NotificationProvider for Fwd {
            fn notify(&self, n: &Notification) {
                self.0.notify(n);
            }
        }
        let multi = MultiNotificationProvider::new()
            .push(Box::new(Fwd(std::sync::Arc::clone(&mem1))))
            .push(Box::new(Fwd(std::sync::Arc::clone(&mem2))));
        multi.notify(&Notification::RunStarted { total: 1, from_cache: 0 });
        assert_eq!(mem1.count(), 1);
        assert_eq!(mem2.count(), 1);
    }
}
