//! The streaming `Run` session handle.
//!
//! [`crate::coordinator::memento::Memento::launch`] returns a [`Run`]
//! instead of blocking until the last task: expansion, execution, and
//! observation are decoupled streams. The run executes on a background
//! thread; every lifecycle transition is published as a typed [`RunEvent`]
//! on an unbounded channel the caller drains at its own pace:
//!
//! ```text
//! let run = memento.launch(&matrix)?;          // returns immediately
//! for event in run.events() {                  // live, as they happen
//!     if let RunEvent::TaskFinished(o) = event { … }
//! }
//! let results = run.collect()?;                // == what run() returns
//! ```
//!
//! `Memento::run()` is preserved verbatim as `launch().collect()`;
//! `Run::cancel()` stops a run mid-flight (nothing new is dispatched and
//! `collect()` returns the partial [`ResultSet`]; thread-backend in-flight
//! tasks finish and are kept, process-backend in-flight attempts are
//! interrupted — their workers are shut down and the interruption
//! journaled — so cancel latency is bounded by a heartbeat, not an
//! attempt).
//!
//! # Event-channel backpressure
//!
//! By default ([`ChannelPolicy::Unbounded`]) events ride an unbounded
//! channel and never block the executing workers — but a caller that
//! holds a `Run` without draining it buffers every outcome twice, which
//! on a 10⁷-task run is an OOM. [`ChannelPolicy::Bounded`] (via
//! `Memento::event_capacity`) caps the channel instead: **terminal
//! events** (`TaskFinished`, `WorkerCrashed`, `RunComplete`, plus
//! `TaskStarted`) are *never dropped* — under pressure their senders
//! block until the consumer catches up (true backpressure) — while
//! intermediate `Progress`/`TaskProgress` events are *coalesced*: a full
//! buffer drops them and counts the drop, and because their payloads are
//! cumulative counters the next one delivered carries the same
//! information. The coalesced-drop count is surfaced on
//! [`RunSummary::events_coalesced`].

use crate::coordinator::error::MementoError;
use crate::coordinator::notify::{Notification, NotificationProvider};
use crate::coordinator::results::{ResultSet, TaskOutcome};
use crate::coordinator::task::TaskId;
use crate::obs::snapshot::MetricsSnapshot;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};

/// Buffering policy for a run's live event channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelPolicy {
    /// Unbounded buffering (the default; `launch()` behavior is
    /// unchanged): sends never block and nothing is ever dropped, at the
    /// cost of unbounded memory if the caller never drains.
    Unbounded,
    /// At most `capacity` undelivered events. Terminal events block their
    /// sender when full (backpressure); intermediate `Progress` /
    /// `TaskProgress` events are coalesced (dropped + counted) instead.
    Bounded {
        /// Maximum undelivered events held by the channel (min 1).
        capacity: usize,
    },
}

impl Default for ChannelPolicy {
    fn default() -> Self {
        ChannelPolicy::Unbounded
    }
}

/// One observable transition of a live run.
#[derive(Debug, Clone)]
pub enum RunEvent {
    /// An attempt of a task began executing (one per attempt, so a retried
    /// task starts more than once).
    TaskStarted {
        /// The task's expansion index.
        index: usize,
        /// The task's content-hash identity.
        id: TaskId,
        /// 1-based attempt number.
        attempt: u32,
    },
    /// A task published in-task partial progress
    /// ([`crate::coordinator::task::TaskContext::save_progress`]); on the
    /// process backend this forwards the worker's `Progress` frames.
    TaskProgress {
        /// The task's expansion index.
        index: usize,
        /// The task's content-hash identity.
        id: TaskId,
        /// The saved progress payload.
        value: Json,
    },
    /// A task reached a terminal state (executed, failed, or restored from
    /// cache/checkpoint — `from_cache` distinguishes them).
    TaskFinished(TaskOutcome),
    /// Run-level progress counters; emitted after every terminal task.
    /// `planned` grows while the lazy expansion is still being consumed
    /// and is final once `planning_complete` is true.
    Progress {
        /// Executed (non-restored) tasks finished so far.
        finished: usize,
        /// Tasks restored from cache or a resumed checkpoint.
        restored: usize,
        /// Tasks abandoned by a fail-fast abort or `cancel()`.
        skipped: usize,
        /// Pending tasks discovered by the lazy expansion so far.
        planned: usize,
        /// True once the expansion stream is exhausted (totals are final).
        planning_complete: bool,
    },
    /// A worker died, was killed as hung, or was stopped at a task's
    /// wall-clock budget (process/remote backends only).
    WorkerCrashed {
        /// The supervisor slot whose worker was lost.
        slot: usize,
        /// What happened, human-readable.
        message: String,
    },
    /// A periodic live telemetry sample (enabled via
    /// `Memento::telemetry_every`). Coalescable under a bounded channel —
    /// every snapshot carries cumulative counters, so a dropped sample
    /// loses nothing the next delivered one doesn't restate.
    Telemetry(MetricsSnapshot),
    /// Terminal event: always the last event of a run.
    RunComplete(RunSummary),
}

/// Final accounting carried by [`RunEvent::RunComplete`].
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    /// Total tasks the run accounted for (executed + restored).
    pub total: usize,
    /// Tasks that finished successfully (restores included).
    pub succeeded: usize,
    /// Tasks whose final outcome was a failure.
    pub failed: usize,
    /// Tasks restored from cache or a resumed checkpoint.
    pub from_cache: usize,
    /// Tasks abandoned by a fail-fast abort or a cancel.
    pub skipped: usize,
    /// Wall-clock duration of the whole run in seconds.
    pub wall_secs: f64,
    /// Intermediate `Progress`/`TaskProgress` events coalesced (dropped
    /// under pressure) by a bounded event channel. Always 0 with the
    /// default unbounded policy; terminal events are never dropped.
    pub events_coalesced: usize,
    /// True when fail-fast stopped the run early.
    pub aborted: bool,
    /// True when [`Run::cancel`] stopped the run early.
    pub cancelled: bool,
    /// The final metrics snapshot (counters, percentiles, per-worker
    /// rows) captured as the run finished; `None` only on early error
    /// paths that never started executing.
    pub metrics: Option<MetricsSnapshot>,
}

impl RunEvent {
    /// Stable machine rendering — one object per event, used by the CLI's
    /// `--output ndjson` mode.
    pub fn to_json(&self) -> Json {
        match self {
            RunEvent::TaskStarted { index, id, attempt } => Json::obj(vec![
                ("event", Json::str("task_started")),
                ("index", Json::int(*index as i64)),
                ("id", Json::str(id.0.clone())),
                ("attempt", Json::int(*attempt as i64)),
            ]),
            RunEvent::TaskProgress { index, id, value } => Json::obj(vec![
                ("event", Json::str("task_progress")),
                ("index", Json::int(*index as i64)),
                ("id", Json::str(id.0.clone())),
                ("value", value.clone()),
            ]),
            RunEvent::TaskFinished(o) => {
                let mut doc = match o.to_json() {
                    Json::Obj(map) => map,
                    _ => Default::default(),
                };
                doc.insert("event".to_string(), Json::str("task_finished"));
                Json::Obj(doc)
            }
            RunEvent::Progress { finished, restored, skipped, planned, planning_complete } => {
                Json::obj(vec![
                    ("event", Json::str("progress")),
                    ("finished", Json::int(*finished as i64)),
                    ("restored", Json::int(*restored as i64)),
                    ("skipped", Json::int(*skipped as i64)),
                    ("planned", Json::int(*planned as i64)),
                    ("planning_complete", Json::Bool(*planning_complete)),
                ])
            }
            RunEvent::WorkerCrashed { slot, message } => Json::obj(vec![
                ("event", Json::str("worker_crashed")),
                ("slot", Json::int(*slot as i64)),
                ("message", Json::str(message.clone())),
            ]),
            RunEvent::Telemetry(snap) => Json::obj(vec![
                ("event", Json::str("telemetry")),
                ("metrics", snap.to_json()),
            ]),
            RunEvent::RunComplete(s) => {
                let mut fields = vec![
                    ("event", Json::str("run_complete")),
                    ("total", Json::int(s.total as i64)),
                    ("succeeded", Json::int(s.succeeded as i64)),
                    ("failed", Json::int(s.failed as i64)),
                    ("from_cache", Json::int(s.from_cache as i64)),
                    ("skipped", Json::int(s.skipped as i64)),
                    ("wall_secs", Json::Num(s.wall_secs)),
                    ("events_coalesced", Json::int(s.events_coalesced as i64)),
                    ("aborted", Json::Bool(s.aborted)),
                    ("cancelled", Json::Bool(s.cancelled)),
                ];
                if let Some(m) = &s.metrics {
                    fields.push(("metrics", m.to_json()));
                }
                Json::obj(fields)
            }
        }
    }
}

/// Shared event publisher: cloneable, silently drops events once the
/// receiver is gone (a caller that dropped its `Run` mid-stream must not
/// wedge the workers).
///
/// Behavior under [`ChannelPolicy::Bounded`]: terminal events block the
/// emitting worker while the buffer is full (backpressure — they are
/// never dropped), intermediate `Progress`/`TaskProgress` events are
/// coalesced instead (dropped and counted in `coalesced`; their cumulative
/// payloads make the next delivered one equivalent). Channel memory is
/// therefore capped regardless of how slowly the `Run` is drained.
///
/// The sender is mutex-wrapped so the sink is `Sync` on every supported
/// toolchain (`mpsc::Sender` itself only became `Sync` in recent Rust).
/// Each clone wraps its own mutex, so a clone blocked on a full bounded
/// channel only serializes emitters sharing that clone.
pub struct EventSink {
    tx: Mutex<SenderKind>,
    /// Shared across clones: intermediate events dropped under pressure.
    coalesced: Arc<AtomicUsize>,
}

#[derive(Clone)]
enum SenderKind {
    Unbounded(Sender<RunEvent>),
    Bounded(SyncSender<RunEvent>),
}

impl Clone for EventSink {
    fn clone(&self) -> Self {
        EventSink {
            tx: Mutex::new(self.tx.lock().unwrap().clone()),
            coalesced: Arc::clone(&self.coalesced),
        }
    }
}

/// True for events a bounded channel may coalesce under pressure: their
/// payloads are cumulative counters, so dropping one loses nothing the
/// next delivered event doesn't carry.
fn coalescable(event: &RunEvent) -> bool {
    matches!(
        event,
        RunEvent::Progress { .. } | RunEvent::TaskProgress { .. } | RunEvent::Telemetry(_)
    )
}

impl EventSink {
    /// Publishes one event (see the type docs for the buffering rules).
    pub fn emit(&self, event: RunEvent) {
        let tx = self.tx.lock().unwrap();
        match &*tx {
            SenderKind::Unbounded(s) => {
                let _ = s.send(event);
            }
            SenderKind::Bounded(s) => {
                if coalescable(&event) {
                    if let Err(TrySendError::Full(_)) = s.try_send(event) {
                        self.coalesced.fetch_add(1, Ordering::SeqCst);
                    }
                } else {
                    // Terminal event: block until the consumer makes room
                    // (Err means the receiver is gone — drop silently).
                    let _ = s.send(event);
                }
            }
        }
    }

    /// Intermediate events coalesced so far (0 under the unbounded
    /// policy). Exact once all emitting workers have finished.
    pub fn coalesced_count(&self) -> usize {
        self.coalesced.load(Ordering::SeqCst)
    }
}

/// Handle to a live run started by `Memento::launch`.
///
/// Lifecycle: `launch → events()/cancel() → collect()`. Dropping a `Run`
/// without calling [`Run::collect`] waits for the run to finish (call
/// [`Run::cancel`] first for a prompt stop) so no background thread
/// outlives its artifacts (cache/checkpoint directories in tests).
pub struct Run {
    rx: Receiver<RunEvent>,
    cancel: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<Result<ResultSet, MementoError>>>,
}

impl Run {
    /// Wires a new handle to its background thread. Internal — called by
    /// `Memento::launch`.
    pub(crate) fn new(
        rx: Receiver<RunEvent>,
        cancel: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<Result<ResultSet, MementoError>>,
    ) -> Run {
        Run { rx, cancel, handle: Some(handle) }
    }

    /// Creates the channel half used by the run thread, under the given
    /// buffering policy.
    pub(crate) fn channel(policy: ChannelPolicy) -> (EventSink, Receiver<RunEvent>) {
        let (kind, rx) = match policy {
            ChannelPolicy::Unbounded => {
                let (tx, rx) = std::sync::mpsc::channel();
                (SenderKind::Unbounded(tx), rx)
            }
            ChannelPolicy::Bounded { capacity } => {
                let (tx, rx) = std::sync::mpsc::sync_channel(capacity.max(1));
                (SenderKind::Bounded(tx), rx)
            }
        };
        (
            EventSink {
                tx: Mutex::new(kind),
                coalesced: Arc::new(AtomicUsize::new(0)),
            },
            rx,
        )
    }

    /// Requests a mid-flight stop: nothing new is dispatched and the
    /// expansion stream is not consumed further. On the thread backend
    /// in-flight tasks finish and are kept; on the process backend busy
    /// workers are shut down (then killed) and their in-flight attempt is
    /// journaled as interrupted, bounding cancel latency by roughly one
    /// heartbeat instead of one attempt. `collect()` then returns the
    /// partial result set promptly.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// True once the handle has observed [`RunEvent::RunComplete`] being
    /// the channel's end (the background thread has finished).
    pub fn is_finished(&self) -> bool {
        self.handle.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }

    /// Blocking: the next event, or `None` once the run is complete and
    /// the stream is drained.
    pub fn next_event(&self) -> Option<RunEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking: an event if one is ready right now.
    pub fn try_event(&self) -> Option<RunEvent> {
        match self.rx.try_recv() {
            Ok(e) => Some(e),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking iterator over the remaining events; ends after
    /// [`RunEvent::RunComplete`].
    pub fn events(&self) -> Events<'_> {
        Events { run: self }
    }

    /// Drains any unread events and blocks until the run finishes,
    /// returning the same `Result<ResultSet, _>` the blocking
    /// `Memento::run()` API returns.
    pub fn collect(mut self) -> Result<ResultSet, MementoError> {
        for _ in self.events() {}
        self.join()
    }

    fn join(&mut self) -> Result<ResultSet, MementoError> {
        match self.handle.take() {
            None => Err(MementoError::config("run already collected")),
            Some(h) => h
                .join()
                .unwrap_or_else(|_| Err(MementoError::ipc("run thread panicked"))),
        }
    }
}

impl Drop for Run {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            // Drain while waiting (as `collect` does) so the rest of the
            // run's events are consumed as they are produced instead of
            // buffering unboundedly in the channel; callers wanting a
            // prompt stop should `cancel()` before dropping.
            while self.rx.recv().is_ok() {}
            let _ = h.join();
        }
    }
}

/// Blocking event iterator borrowed from a [`Run`].
pub struct Events<'r> {
    run: &'r Run,
}

impl Iterator for Events<'_> {
    type Item = RunEvent;

    fn next(&mut self) -> Option<RunEvent> {
        self.run.next_event()
    }
}

/// Notification ordering gate for the streaming pipeline.
///
/// The eager pipeline emitted `RunStarted` (with exact totals) before any
/// task ran. The streaming pipeline only knows the totals once the lazy
/// expansion is exhausted — which can be *after* the first task fails. To
/// keep the provider-visible ordering contract (`RunStarted` first, exact
/// totals), task-level notifications are buffered until [`open`] runs with
/// the final counts; from then on everything passes straight through. For
/// any realistic matrix, planning completes long before the first outcome,
/// so live behavior is unchanged.
///
/// [`open`]: GatedNotifier::open
pub struct GatedNotifier {
    inner: Arc<dyn NotificationProvider>,
    state: Mutex<GateState>,
}

struct GateState {
    open: bool,
    buffered: Vec<Notification>,
}

impl GatedNotifier {
    /// Wraps a provider behind a closed gate.
    pub fn new(inner: Arc<dyn NotificationProvider>) -> Arc<GatedNotifier> {
        Arc::new(GatedNotifier {
            inner,
            state: Mutex::new(GateState { open: false, buffered: Vec::new() }),
        })
    }

    /// Emits `RunStarted` and flushes everything buffered behind it.
    ///
    /// All provider calls happen while the state lock is held (here and in
    /// [`NotificationProvider::notify`]): releasing the lock between
    /// marking the gate open and emitting `RunStarted` would let a
    /// concurrent task notification slip through first, which is exactly
    /// the inversion the gate exists to prevent. Providers must not call
    /// back into the gate (none do — they are terminal sinks).
    pub fn open(&self, total: usize, from_cache: usize) {
        let mut st = self.state.lock().unwrap();
        if st.open {
            return;
        }
        st.open = true;
        let drained = std::mem::take(&mut st.buffered);
        self.inner.notify(&Notification::RunStarted { total, from_cache });
        for n in drained {
            self.inner.notify(&n);
        }
    }

    /// Flushes without a `RunStarted` (aborted/cancelled before planning
    /// finished) so terminal notifications are never lost.
    pub fn flush(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = true;
        let drained = std::mem::take(&mut st.buffered);
        for n in drained {
            self.inner.notify(&n);
        }
    }
}

impl NotificationProvider for GatedNotifier {
    fn notify(&self, n: &Notification) {
        // Pass-through also happens under the lock, serializing against
        // `open`/`flush` so provider-visible ordering is exactly the gate
        // order.
        let mut st = self.state.lock().unwrap();
        if !st.open {
            st.buffered.push(n.clone());
            return;
        }
        self.inner.notify(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::notify::MemoryNotificationProvider;

    #[test]
    fn gate_buffers_until_open_then_passes_through() {
        let mem = Arc::new(MemoryNotificationProvider::new());
        let gate = GatedNotifier::new(mem.clone() as Arc<dyn NotificationProvider>);
        let failure = crate::coordinator::error::TaskFailure {
            kind: crate::coordinator::error::FailureKind::Error,
            message: "x".into(),
            params: vec![],
            attempts: 1,
        };
        gate.notify(&Notification::TaskFailed { failure: failure.clone() });
        assert_eq!(mem.count(), 0, "buffered before open");
        gate.open(5, 2);
        assert_eq!(mem.count(), 2, "RunStarted + flushed failure");
        assert!(matches!(
            mem.events()[0],
            Notification::RunStarted { total: 5, from_cache: 2 }
        ));
        gate.notify(&Notification::TaskFailed { failure });
        assert_eq!(mem.count(), 3, "live after open");
        gate.open(9, 9);
        assert_eq!(mem.count(), 3, "second open is a no-op");
    }

    #[test]
    fn gate_flush_without_start_keeps_notifications() {
        let mem = Arc::new(MemoryNotificationProvider::new());
        let gate = GatedNotifier::new(mem.clone() as Arc<dyn NotificationProvider>);
        gate.notify(&Notification::RunFinished {
            total: 0,
            succeeded: 0,
            failed: 0,
            from_cache: 0,
            wall_secs: 0.0,
        });
        assert_eq!(mem.count(), 0);
        gate.flush();
        assert_eq!(mem.count(), 1);
    }

    fn progress_event(finished: usize) -> RunEvent {
        RunEvent::Progress {
            finished,
            restored: 0,
            skipped: 0,
            planned: finished,
            planning_complete: false,
        }
    }

    #[test]
    fn unbounded_sink_never_drops_or_counts() {
        let (sink, rx) = Run::channel(ChannelPolicy::Unbounded);
        for i in 0..100 {
            sink.emit(progress_event(i));
        }
        drop(sink);
        assert_eq!(rx.iter().count(), 100);
    }

    #[test]
    fn bounded_sink_coalesces_progress_and_blocks_terminal() {
        let (sink, rx) = Run::channel(ChannelPolicy::Bounded { capacity: 1 });
        // Fill the single-slot buffer with a terminal event.
        sink.emit(RunEvent::WorkerCrashed { slot: 0, message: "x".into() });
        // Intermediate events under pressure are coalesced, not delivered.
        sink.emit(progress_event(1));
        sink.emit(progress_event(2));
        assert_eq!(sink.coalesced_count(), 2);
        // A terminal event blocks its sender until the consumer drains.
        let s2 = sink.clone();
        let t = std::thread::spawn(move || {
            s2.emit(RunEvent::RunComplete(RunSummary::default()));
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!t.is_finished(), "terminal send must backpressure while full");
        assert!(matches!(rx.recv().unwrap(), RunEvent::WorkerCrashed { .. }));
        t.join().unwrap();
        assert!(matches!(rx.recv().unwrap(), RunEvent::RunComplete(_)));
        // Room again: intermediate events flow and the count stays put.
        sink.emit(progress_event(3));
        assert!(matches!(rx.recv().unwrap(), RunEvent::Progress { .. }));
        assert_eq!(sink.coalesced_count(), 2);
    }

    #[test]
    fn bounded_sink_drops_silently_when_receiver_gone() {
        let (sink, rx) = Run::channel(ChannelPolicy::Bounded { capacity: 2 });
        drop(rx);
        // Neither blocks nor panics once the Run is gone.
        sink.emit(RunEvent::RunComplete(RunSummary::default()));
        sink.emit(progress_event(1));
    }

    #[test]
    fn event_json_shapes() {
        let e = RunEvent::Progress {
            finished: 3,
            restored: 1,
            skipped: 0,
            planned: 5,
            planning_complete: true,
        };
        let j = e.to_json();
        assert_eq!(j.get("event").unwrap().as_str(), Some("progress"));
        assert_eq!(j.get("finished").unwrap().as_i64(), Some(3));
        assert_eq!(j.get("planning_complete").unwrap().as_bool(), Some(true));

        let c = RunEvent::WorkerCrashed { slot: 2, message: "died".into() };
        assert_eq!(c.to_json().get("slot").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn telemetry_event_is_coalescable_and_serializable() {
        let snap = MetricsSnapshot { tasks_total: 7, ..Default::default() };
        let e = RunEvent::Telemetry(snap);
        assert!(coalescable(&e), "telemetry must never block terminal events");
        let j = e.to_json();
        assert_eq!(j.get("event").unwrap().as_str(), Some("telemetry"));
        let m = j.get("metrics").expect("embedded snapshot");
        assert_eq!(m.get("tasks_total").unwrap().as_i64(), Some(7));
    }

    #[test]
    fn run_complete_json_carries_the_final_snapshot() {
        let bare = RunEvent::RunComplete(RunSummary::default()).to_json();
        assert!(bare.get("metrics").is_none(), "no snapshot on early-error paths");

        let done = RunEvent::RunComplete(RunSummary {
            total: 2,
            metrics: Some(MetricsSnapshot { tasks_total: 2, ..Default::default() }),
            ..Default::default()
        });
        let j = done.to_json();
        assert_eq!(j.get("metrics").unwrap().get("tasks_total").unwrap().as_i64(), Some(2));
    }
}
