//! Serializable point-in-time metrics: [`MetricsSnapshot`] and the
//! live per-worker registry ([`FleetStats`]) it samples.
//!
//! A snapshot freezes the run's `RunMetrics` counters and latency
//! percentiles together with fleet state — queue depth, the windowed
//! observed completion rate (the same window the ETA uses), and one
//! [`WorkerStat`] row per worker (tasks completed, heartbeat age,
//! crash-budget remaining). Snapshots are plain data: they ride in
//! `RunEvent::Telemetry`, land in the final `RunSummary`, and persist
//! as `metrics.snap` (storage codec, auto-detected on read) so
//! `memento status` can show the last known state of a run directory.

use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::progress::ProgressState;
use crate::util::codec::{self, WireFormat};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// File name of the persisted final snapshot inside a run directory.
pub const SNAPSHOT_FILE: &str = "metrics.snap";

/// One worker's row in a snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerStat {
    /// Worker id (supervisor slot, or thread-backend thread id).
    pub worker: u64,
    /// Tasks this worker has completed so far.
    pub completed: u64,
    /// Seconds since the worker was last heard from, when tracked.
    pub heartbeat_age_secs: Option<f64>,
    /// Crash budget remaining on this slot, when the backend has one.
    pub crash_budget_remaining: Option<u32>,
}

impl WorkerStat {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("worker", Json::int(self.worker as i64)),
            ("completed", Json::int(self.completed as i64)),
        ];
        if let Some(age) = self.heartbeat_age_secs {
            fields.push(("heartbeat_age_secs", Json::num(age)));
        }
        if let Some(b) = self.crash_budget_remaining {
            fields.push(("crash_budget_remaining", Json::int(b as i64)));
        }
        Json::obj(fields)
    }

    fn from_json(doc: &Json) -> Option<WorkerStat> {
        Some(WorkerStat {
            worker: doc.get("worker")?.as_i64()? as u64,
            completed: doc.get("completed")?.as_i64()? as u64,
            heartbeat_age_secs: doc.get("heartbeat_age_secs").and_then(Json::as_f64),
            crash_budget_remaining: doc
                .get("crash_budget_remaining")
                .and_then(Json::as_i64)
                .map(|b| b as u32),
        })
    }
}

/// A serializable point-in-time capture of run metrics plus fleet
/// state. All counters are monotonic within a run; a sequence of
/// snapshots is a time series.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// UNIX microseconds at capture time.
    pub unix_us: u64,
    /// Seconds since the run started.
    pub wall_secs: f64,
    /// Terminal outcomes recorded so far (success + failure + cached).
    pub tasks_total: u64,
    /// Tasks that executed and succeeded.
    pub tasks_succeeded: u64,
    /// Tasks that exhausted retries and failed.
    pub tasks_failed: u64,
    /// Tasks satisfied from the result cache.
    pub tasks_cached: u64,
    /// Attempts that failed and were retried.
    pub tasks_retried: u64,
    /// Attempts killed by the per-task wall-clock timeout.
    pub tasks_timed_out: u64,
    /// Specs abandoned by a fail-fast abort.
    pub tasks_skipped: u64,
    /// Result-cache hits.
    pub cache_hits: u64,
    /// Result-cache misses.
    pub cache_misses: u64,
    /// Checkpoint batches flushed to disk.
    pub checkpoint_flushes: u64,
    /// Work-stealing dispatch chunks handed out.
    pub dispatch_chunks: u64,
    /// Tasks obtained by stealing from another worker's deque.
    pub steals: u64,
    /// Mean experiment execution time, seconds.
    pub exec_mean_secs: f64,
    /// Median experiment execution time, seconds.
    pub exec_p50_secs: f64,
    /// 95th-percentile experiment execution time, seconds.
    pub exec_p95_secs: f64,
    /// Median dispatch overhead (coordination cost per task), seconds.
    pub dispatch_p50_secs: f64,
    /// 95th-percentile dispatch overhead, seconds.
    pub dispatch_p95_secs: f64,
    /// Planned tasks not yet finished, restored, or skipped.
    pub queue_depth: u64,
    /// Windowed observed completion rate (tasks/second), `None` until
    /// two spaced completions exist — the same window the ETA uses.
    pub observed_rate: Option<f64>,
    /// Per-worker rows, sorted by worker id.
    pub workers: Vec<WorkerStat>,
}

impl MetricsSnapshot {
    /// Captures a snapshot from the live run state. `progress` supplies
    /// queue depth and the observed rate; `fleet` supplies per-worker
    /// rows; both are optional so backends can report what they have.
    pub fn capture(
        metrics: &RunMetrics,
        progress: Option<&ProgressState>,
        fleet: Option<&FleetStats>,
        wall_secs: f64,
    ) -> MetricsSnapshot {
        let unix_us = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let (queue_depth, observed_rate) = match progress {
            Some(p) => {
                let (done, skipped, total) = p.snapshot_full();
                let outstanding = total.saturating_sub(done + skipped + p.restored_count());
                (outstanding as u64, p.recent_rate())
            }
            None => (0, None),
        };
        MetricsSnapshot {
            unix_us,
            wall_secs,
            tasks_total: metrics.tasks_total.get(),
            tasks_succeeded: metrics.tasks_succeeded.get(),
            tasks_failed: metrics.tasks_failed.get(),
            tasks_cached: metrics.tasks_cached.get(),
            tasks_retried: metrics.tasks_retried.get(),
            tasks_timed_out: metrics.tasks_timed_out.get(),
            tasks_skipped: metrics.tasks_skipped.get(),
            cache_hits: metrics.cache_hits.get(),
            cache_misses: metrics.cache_misses.get(),
            checkpoint_flushes: metrics.checkpoint_flushes.get(),
            dispatch_chunks: metrics.dispatch_chunks.get(),
            steals: metrics.steals.get(),
            exec_mean_secs: metrics.exec_time.mean().as_secs_f64(),
            exec_p50_secs: metrics.exec_time.percentile(0.50).as_secs_f64(),
            exec_p95_secs: metrics.exec_time.percentile(0.95).as_secs_f64(),
            dispatch_p50_secs: metrics.dispatch_overhead.percentile(0.50).as_secs_f64(),
            dispatch_p95_secs: metrics.dispatch_overhead.percentile(0.95).as_secs_f64(),
            queue_depth,
            observed_rate,
            workers: fleet.map(FleetStats::snapshot).unwrap_or_default(),
        }
    }

    /// Serializes the snapshot as a flat JSON object (plus a `workers`
    /// array).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("unix_us", Json::int(self.unix_us as i64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("tasks_total", Json::int(self.tasks_total as i64)),
            ("tasks_succeeded", Json::int(self.tasks_succeeded as i64)),
            ("tasks_failed", Json::int(self.tasks_failed as i64)),
            ("tasks_cached", Json::int(self.tasks_cached as i64)),
            ("tasks_retried", Json::int(self.tasks_retried as i64)),
            ("tasks_timed_out", Json::int(self.tasks_timed_out as i64)),
            ("tasks_skipped", Json::int(self.tasks_skipped as i64)),
            ("cache_hits", Json::int(self.cache_hits as i64)),
            ("cache_misses", Json::int(self.cache_misses as i64)),
            ("checkpoint_flushes", Json::int(self.checkpoint_flushes as i64)),
            ("dispatch_chunks", Json::int(self.dispatch_chunks as i64)),
            ("steals", Json::int(self.steals as i64)),
            ("exec_mean_secs", Json::num(self.exec_mean_secs)),
            ("exec_p50_secs", Json::num(self.exec_p50_secs)),
            ("exec_p95_secs", Json::num(self.exec_p95_secs)),
            ("dispatch_p50_secs", Json::num(self.dispatch_p50_secs)),
            ("dispatch_p95_secs", Json::num(self.dispatch_p95_secs)),
            ("queue_depth", Json::int(self.queue_depth as i64)),
            ("workers", Json::arr(self.workers.iter().map(WorkerStat::to_json).collect())),
        ];
        if let Some(rate) = self.observed_rate {
            fields.push(("observed_rate", Json::num(rate)));
        }
        Json::obj(fields)
    }

    /// Parses a snapshot from its JSON form. Missing numeric fields
    /// default to zero so older snapshots keep loading as the schema
    /// grows (the same tolerant-reader pattern the wire protocol uses).
    pub fn from_json(doc: &Json) -> Option<MetricsSnapshot> {
        doc.as_obj()?;
        let int = |key: &str| doc.get(key).and_then(Json::as_i64).unwrap_or(0) as u64;
        let num = |key: &str| doc.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        let workers = match doc.get("workers") {
            Some(Json::Arr(items)) => items.iter().filter_map(WorkerStat::from_json).collect(),
            _ => Vec::new(),
        };
        Some(MetricsSnapshot {
            unix_us: int("unix_us"),
            wall_secs: num("wall_secs"),
            tasks_total: int("tasks_total"),
            tasks_succeeded: int("tasks_succeeded"),
            tasks_failed: int("tasks_failed"),
            tasks_cached: int("tasks_cached"),
            tasks_retried: int("tasks_retried"),
            tasks_timed_out: int("tasks_timed_out"),
            tasks_skipped: int("tasks_skipped"),
            cache_hits: int("cache_hits"),
            cache_misses: int("cache_misses"),
            checkpoint_flushes: int("checkpoint_flushes"),
            dispatch_chunks: int("dispatch_chunks"),
            steals: int("steals"),
            exec_mean_secs: num("exec_mean_secs"),
            exec_p50_secs: num("exec_p50_secs"),
            exec_p95_secs: num("exec_p95_secs"),
            dispatch_p50_secs: num("dispatch_p50_secs"),
            dispatch_p95_secs: num("dispatch_p95_secs"),
            queue_depth: int("queue_depth"),
            observed_rate: doc.get("observed_rate").and_then(Json::as_f64),
            workers,
        })
    }

    /// Renders the snapshot as the text block `memento status` prints.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "metrics: {} recorded ({} ok, {} failed, {} cached, {} skipped) in {:.2}s\n",
            self.tasks_total,
            self.tasks_succeeded,
            self.tasks_failed,
            self.tasks_cached,
            self.tasks_skipped,
            self.wall_secs
        ));
        out.push_str(&format!(
            "  exec p50 {:.4}s  p95 {:.4}s  mean {:.4}s   dispatch p50 {:.6}s  p95 {:.6}s\n",
            self.exec_p50_secs,
            self.exec_p95_secs,
            self.exec_mean_secs,
            self.dispatch_p50_secs,
            self.dispatch_p95_secs
        ));
        out.push_str(&format!(
            "  queue depth {}   retries {}   timeouts {}   cache {}/{} hit\n",
            self.queue_depth,
            self.tasks_retried,
            self.tasks_timed_out,
            self.cache_hits,
            self.cache_hits + self.cache_misses
        ));
        if let Some(rate) = self.observed_rate {
            out.push_str(&format!("  observed rate {rate:.1} tasks/s\n"));
        }
        for w in &self.workers {
            let hb = w
                .heartbeat_age_secs
                .map(|a| format!(", heard {a:.1}s ago"))
                .unwrap_or_default();
            let budget = w
                .crash_budget_remaining
                .map(|b| format!(", crash budget {b}"))
                .unwrap_or_default();
            out.push_str(&format!(
                "  worker {:>3}: {} completed{hb}{budget}\n",
                w.worker, w.completed
            ));
        }
        out
    }
}

/// Writes a snapshot to `dir/metrics.snap` atomically in the given
/// storage format.
pub fn write_snapshot(dir: &Path, snap: &MetricsSnapshot, format: WireFormat) -> io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let bytes = codec::write_document(&snap.to_json(), format);
    crate::util::fs::atomic_write(&dir.join(SNAPSHOT_FILE), &bytes)
}

/// Reads `dir/metrics.snap` back, auto-detecting the storage format.
/// `None` when the file is absent or unreadable.
pub fn read_snapshot(dir: &Path) -> Option<MetricsSnapshot> {
    let bytes = std::fs::read(dir.join(SNAPSHOT_FILE)).ok()?;
    let doc = codec::read_document(&bytes).ok()?;
    MetricsSnapshot::from_json(&doc)
}

#[derive(Default)]
struct WorkerEntry {
    completed: u64,
    last_seen: Option<Instant>,
    budget_remaining: Option<u32>,
}

/// Live per-worker activity registry sampled by
/// [`MetricsSnapshot::capture`]. Backends feed it what they know: the
/// supervisor reports completions, heartbeats, and crash budgets per
/// slot; the thread backend reports completions per pool thread.
#[derive(Default)]
pub struct FleetStats {
    workers: Mutex<BTreeMap<u64, WorkerEntry>>,
}

impl FleetStats {
    /// An empty registry.
    pub fn new() -> FleetStats {
        FleetStats::default()
    }

    /// Records one completed task on `worker` (also counts as hearing
    /// from it).
    pub fn task_completed(&self, worker: u64) {
        let mut map = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        let entry = map.entry(worker).or_default();
        entry.completed += 1;
        entry.last_seen = Some(Instant::now());
    }

    /// Records a liveness signal (heartbeat frame, chunk pickup) from
    /// `worker`.
    pub fn heartbeat(&self, worker: u64) {
        let mut map = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(worker).or_default().last_seen = Some(Instant::now());
    }

    /// Updates the crash budget remaining on `worker`'s slot.
    pub fn set_crash_budget_remaining(&self, worker: u64, remaining: u32) {
        let mut map = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        map.entry(worker).or_default().budget_remaining = Some(remaining);
    }

    /// Freezes the registry into per-worker rows, sorted by worker id.
    pub fn snapshot(&self) -> Vec<WorkerStat> {
        let map = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        map.iter()
            .map(|(worker, e)| WorkerStat {
                worker: *worker,
                completed: e.completed,
                heartbeat_age_secs: e.last_seen.map(|t| t.elapsed().as_secs_f64()),
                crash_budget_remaining: e.budget_remaining,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsSnapshot {
        MetricsSnapshot {
            unix_us: 1_700_000_000_000_000,
            wall_secs: 12.5,
            tasks_total: 100,
            tasks_succeeded: 90,
            tasks_failed: 4,
            tasks_cached: 6,
            tasks_retried: 3,
            tasks_timed_out: 1,
            tasks_skipped: 0,
            cache_hits: 6,
            cache_misses: 94,
            checkpoint_flushes: 10,
            dispatch_chunks: 25,
            steals: 7,
            exec_mean_secs: 0.05,
            exec_p50_secs: 0.04,
            exec_p95_secs: 0.2,
            dispatch_p50_secs: 0.0001,
            dispatch_p95_secs: 0.001,
            queue_depth: 12,
            observed_rate: Some(8.25),
            workers: vec![
                WorkerStat {
                    worker: 0,
                    completed: 50,
                    heartbeat_age_secs: Some(0.5),
                    crash_budget_remaining: Some(2),
                },
                WorkerStat {
                    worker: 1,
                    completed: 44,
                    heartbeat_age_secs: None,
                    crash_budget_remaining: None,
                },
            ],
        }
    }

    #[test]
    fn snapshot_roundtrips_in_both_codec_formats() {
        let original = sample();
        for format in [WireFormat::Json, WireFormat::Binary] {
            let bytes = codec::write_document(&original.to_json(), format);
            let doc = codec::read_document(&bytes).expect("decode");
            let back = MetricsSnapshot::from_json(&doc).expect("parse");
            assert_eq!(back, original);
        }
    }

    #[test]
    fn snapshot_tolerates_missing_fields() {
        let doc = crate::util::json::parse(r#"{"tasks_total":5}"#).unwrap();
        let snap = MetricsSnapshot::from_json(&doc).expect("parse");
        assert_eq!(snap.tasks_total, 5);
        assert_eq!(snap.tasks_succeeded, 0);
        assert_eq!(snap.observed_rate, None);
        assert!(snap.workers.is_empty());
    }

    #[test]
    fn snapshot_file_write_read_roundtrip() {
        for format in [WireFormat::Json, WireFormat::Binary] {
            let dir = crate::util::fs::TempDir::new("snap").expect("tempdir");
            let original = sample();
            write_snapshot(dir.path(), &original, format).expect("write");
            let back = read_snapshot(dir.path()).expect("read");
            assert_eq!(back, original);
        }
    }

    #[test]
    fn capture_reads_metrics_progress_and_fleet() {
        let metrics = RunMetrics::default();
        metrics.tasks_total.add(3);
        metrics.tasks_succeeded.add(3);
        metrics.exec_time.record(std::time::Duration::from_millis(10));

        let progress = ProgressState::new(10);
        progress.mark_done();
        progress.mark_done();

        let fleet = FleetStats::new();
        fleet.task_completed(0);
        fleet.task_completed(0);
        fleet.task_completed(1);
        fleet.set_crash_budget_remaining(1, 3);

        let snap = MetricsSnapshot::capture(&metrics, Some(&progress), Some(&fleet), 1.0);
        assert_eq!(snap.tasks_total, 3);
        assert_eq!(snap.queue_depth, 8);
        assert!(snap.exec_mean_secs > 0.0);
        assert_eq!(snap.workers.len(), 2);
        assert_eq!(snap.workers[0].completed, 2);
        assert_eq!(snap.workers[1].crash_budget_remaining, Some(3));
        assert!(snap.workers[0].heartbeat_age_secs.is_some());
        assert!(!snap.render().is_empty());
    }

    #[test]
    fn fleet_heartbeat_updates_age_without_completions() {
        let fleet = FleetStats::new();
        fleet.heartbeat(5);
        let rows = fleet.snapshot();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].worker, 5);
        assert_eq!(rows[0].completed, 0);
        assert!(rows[0].heartbeat_age_secs.is_some());
    }
}
